//! The request service: worker threads pull batches from the dynamic
//! batcher and execute them on the shared [`Engine`], answering through
//! per-request oneshot channels.

// rustc-side twin of the xtask no-panic-in-serving rule: serving code
// must propagate errors. Test code (crate-wide `cfg(test)` under
// `cargo test`) is exempt on purpose.
#![cfg_attr(not(test), warn(clippy::unwrap_used, clippy::expect_used))]

use std::sync::mpsc;
use std::sync::{Arc, OnceLock};
use std::thread::JoinHandle;
use std::time::Instant;

use super::batcher::{BatcherConfig, DynamicBatcher};
use super::engine::{Engine, Request, Response};
use super::metrics::{Metrics, MetricsSnapshot};
use crate::obs::prometheus::PromText;
use crate::obs::QueryTrace;

/// Service sizing.
#[derive(Debug, Clone, Copy)]
pub struct ServiceConfig {
    /// Worker threads.
    pub n_workers: usize,
    /// Batching policy.
    pub batcher: BatcherConfig,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig { n_workers: 2, batcher: BatcherConfig::default() }
    }
}

struct Job {
    request: Request,
    submitted: Instant,
    /// Client asked for a trace + per-hit explanations on this request.
    trace: bool,
    reply: mpsc::Sender<(Response, Option<QueryTrace>)>,
}

/// A running similarity-search service. Cloneable handles are cheap
/// (everything shared is behind `Arc`).
pub struct Service {
    batcher: Arc<DynamicBatcher<Job>>,
    metrics: Arc<Metrics>,
    engine: Arc<Engine>,
    started: Instant,
    workers: Vec<JoinHandle<()>>,
    /// Optional durable job plane; attached once after startup when the
    /// process enables background jobs (`serve --listen`).
    jobs: OnceLock<Arc<crate::jobs::JobManager>>,
}

impl Service {
    /// Start `cfg.n_workers` workers over a shared engine.
    pub fn start(engine: Arc<Engine>, cfg: ServiceConfig) -> Self {
        let batcher: Arc<DynamicBatcher<Job>> = Arc::new(DynamicBatcher::new(cfg.batcher));
        let metrics = Arc::new(Metrics::new());
        let mut workers = Vec::with_capacity(cfg.n_workers);
        for _ in 0..cfg.n_workers.max(1) {
            let batcher = Arc::clone(&batcher);
            let metrics = Arc::clone(&metrics);
            let engine = Arc::clone(&engine);
            workers.push(std::thread::spawn(move || {
                while let Some(batch) = batcher.next_batch() {
                    metrics.record_batch(batch.len());
                    for job in batch {
                        let class = job.request.class();
                        let (resp, trace) = engine.handle_traced(&job.request, job.trace);
                        // Stage spans feed the per-stage latency
                        // histograms whether or not the client asked
                        // for the trace back.
                        if let Some(t) = &trace {
                            for span in &t.spans {
                                metrics.record_stage(span.stage, span.wall_us);
                            }
                        }
                        let is_err = matches!(resp, Response::Error(_));
                        let latency = job.submitted.elapsed().as_micros() as u64;
                        metrics.record_request(class, latency, is_err);
                        let trace = if job.trace { trace } else { None };
                        // Receiver may have given up; that's fine.
                        let _ = job.reply.send((resp, trace));
                    }
                }
            }));
        }
        Service {
            batcher,
            metrics,
            engine,
            started: Instant::now(),
            workers,
            jobs: OnceLock::new(),
        }
    }

    /// Attach a durable job manager. First attach wins; later calls are
    /// ignored (the plane is wired exactly once at startup).
    pub fn attach_jobs(&self, manager: Arc<crate::jobs::JobManager>) {
        let _ = self.jobs.set(manager);
    }

    /// The attached job manager, if the job plane is enabled.
    pub fn jobs(&self) -> Option<&Arc<crate::jobs::JobManager>> {
        self.jobs.get()
    }

    /// Submit a request; returns a oneshot receiver for the response
    /// (trace slot always `None`). `None` if the service is shutting
    /// down.
    pub fn submit(
        &self,
        request: Request,
    ) -> Option<mpsc::Receiver<(Response, Option<QueryTrace>)>> {
        self.submit_traced(request, false)
    }

    /// Submit a request, optionally asking for a [`QueryTrace`] with
    /// per-hit explanations alongside the response.
    pub fn submit_traced(
        &self,
        request: Request,
        trace: bool,
    ) -> Option<mpsc::Receiver<(Response, Option<QueryTrace>)>> {
        let (tx, rx) = mpsc::channel();
        let ok = self
            .batcher
            .push(Job { request, submitted: Instant::now(), trace, reply: tx });
        ok.then_some(rx)
    }

    /// Convenience: submit and block for the response.
    pub fn call(&self, request: Request) -> Response {
        self.call_traced(request, false).0
    }

    /// Convenience: submit with a trace request and block for both.
    pub fn call_traced(
        &self,
        request: Request,
        trace: bool,
    ) -> (Response, Option<QueryTrace>) {
        match self.submit_traced(request, trace) {
            Some(rx) => rx.recv().unwrap_or_else(|_| {
                (Response::Error("worker dropped request".into()), None)
            }),
            None => (Response::Error("service closed".into()), None),
        }
    }

    /// Current metrics.
    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// The shared engine (index header summary, scan counters).
    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Whole seconds since `start`.
    pub fn uptime_s(&self) -> u64 {
        self.started.elapsed().as_secs()
    }

    /// Render the full Prometheus text exposition for this service:
    /// request/stage metrics, engine-wide prune-cascade counters, index
    /// header gauges, uptime, and build info.
    pub fn prometheus_text(&self) -> String {
        let mut p = PromText::new();
        self.metrics.render_prometheus(&mut p);
        let scan = self.engine.scan_stats();
        p.counter("pqdtw_scan_items_scanned_total", scan.items_scanned);
        p.counter("pqdtw_scan_items_abandoned_total", scan.items_abandoned);
        p.counter("pqdtw_scan_blocks_skipped_total", scan.blocks_skipped);
        p.counter("pqdtw_scan_lut_collapses_total", scan.lut_collapses);
        p.counter("pqdtw_scan_shard_time_microseconds_total", scan.shard_time_us);
        let info = self.engine.info();
        p.gauge("pqdtw_index_items", info.n_items as f64);
        p.gauge("pqdtw_index_subspaces", info.n_subspaces as f64);
        p.gauge("pqdtw_index_codebook_size", info.codebook_size as f64);
        p.gauge("pqdtw_index_series_len", info.series_len as f64);
        p.gauge("pqdtw_index_window_frac", info.window_frac);
        p.gauge(
            "pqdtw_index_ivf_lists",
            info.nlist.map(|n| n as f64).unwrap_or(0.0),
        );
        p.gauge("pqdtw_queue_depth", self.queue_depth() as f64);
        p.gauge("pqdtw_uptime_seconds", self.started.elapsed().as_secs_f64());
        if let Some(jobs) = self.jobs.get() {
            jobs.render_prometheus(&mut p);
        }
        p.family("pqdtw_build_info", "gauge");
        p.sample(
            "pqdtw_build_info",
            &[
                ("version", env!("CARGO_PKG_VERSION")),
                ("coarse_metric", info.coarse_metric.as_str()),
            ],
            1.0,
        );
        p.finish()
    }

    /// JSON body for the scrape endpoint's `GET /healthz` route. A
    /// single-node server that can answer at all is healthy; the body
    /// carries uptime and queue depth so a probe can watch for
    /// backpressure without parsing the full exposition.
    pub fn healthz_json(&self) -> String {
        format!(
            "{{\"status\":\"ok\",\"uptime_s\":{},\"queue_depth\":{}}}",
            self.uptime_s(),
            self.queue_depth()
        )
    }

    /// Bump the slow-query counter. Threshold detection lives in the
    /// serving planes (`serve --slow-query-ms`); the counter lives here
    /// so `pqdtw_slow_queries_total` renders with the rest of the
    /// request metrics.
    pub fn record_slow_query(&self) {
        self.metrics.record_slow_query();
    }

    /// Record a request served outside the engine path — e.g. the
    /// network plane's ping/stats frames — into the same metrics sink,
    /// so a remote `stats` call accounts for every request class.
    pub fn record_external(
        &self,
        class: super::metrics::RequestClass,
        latency_us: u64,
        is_error: bool,
    ) {
        self.metrics.record_request(class, latency_us, is_error);
    }

    /// Queue depth (backpressure signal).
    pub fn queue_depth(&self) -> usize {
        self.batcher.depth()
    }

    /// Drain and stop all workers.
    pub fn shutdown(mut self) -> MetricsSnapshot {
        self.batcher.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        self.metrics.snapshot()
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        // Close *and join*: merely closing the batcher would let worker
        // threads race process exit, silently dropping in-flight
        // replies (`drop_delivers_in_flight_replies` is the regression
        // test). `shutdown()` drains `workers`, so a second pass here
        // is a no-op.
        self.batcher.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::ucr_like::ucr_like_by_name;
    use crate::nn::knn::PqQueryMode;
    use crate::pq::quantizer::PqConfig;

    fn toy_service(n_workers: usize) -> (Service, crate::core::series::Dataset) {
        let tt = ucr_like_by_name("SpikePosition", 43).unwrap();
        let cfg = PqConfig {
            n_subspaces: 4,
            codebook_size: 8,
            window_frac: 0.2,
            ..Default::default()
        };
        let engine = Arc::new(Engine::build(&tt.train, &cfg, 1).unwrap());
        let svc = Service::start(
            engine,
            ServiceConfig { n_workers, batcher: BatcherConfig::default() },
        );
        (svc, tt.test)
    }

    #[test]
    fn serves_blocking_calls() {
        let (svc, test) = toy_service(2);
        for i in 0..5 {
            match svc.call(Request::NnQuery {
                series: test.row(i).to_vec(),
                mode: PqQueryMode::Symmetric,
                nprobe: None,
            }) {
                Response::Nn { distance, .. } => assert!(distance.is_finite()),
                other => panic!("unexpected {other:?}"),
            }
        }
        let m = svc.shutdown();
        assert_eq!(m.requests, 5);
        assert_eq!(m.errors, 0);
        assert!(m.batches >= 1);
        assert_eq!(m.class(crate::coordinator::metrics::RequestClass::Nn).requests, 5);
    }

    #[test]
    fn concurrent_clients() {
        let (svc, test) = toy_service(3);
        let svc = Arc::new(svc);
        let test = Arc::new(test);
        let mut handles = Vec::new();
        for t in 0..4 {
            let svc = Arc::clone(&svc);
            let test = Arc::clone(&test);
            handles.push(std::thread::spawn(move || {
                for i in 0..8 {
                    let idx = (t * 8 + i) % test.n_series();
                    let r = svc.call(Request::Encode { series: test.row(idx).to_vec() });
                    assert!(matches!(r, Response::Codes(_)));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let m = svc.metrics();
        assert_eq!(m.requests, 32);
    }

    #[test]
    fn error_requests_counted() {
        let (svc, _) = toy_service(1);
        let r = svc.call(Request::Encode { series: vec![1.0, 2.0] });
        assert!(matches!(r, Response::Error(_)));
        let m = svc.shutdown();
        assert_eq!(m.errors, 1);
    }

    #[test]
    fn drop_delivers_in_flight_replies() {
        // Teardown regression: dropping the service must close the
        // batcher AND join the workers, so every request submitted
        // before the drop still gets its reply (workers drain the queue
        // before exiting). Without the joins, replies race process
        // teardown and are silently lost.
        let (svc, test) = toy_service(2);
        let mut pending = Vec::new();
        for i in 0..6 {
            let rx = svc
                .submit(Request::Encode { series: test.row(i).to_vec() })
                .expect("service accepts requests before drop");
            pending.push(rx);
        }
        drop(svc);
        for (i, rx) in pending.into_iter().enumerate() {
            let (resp, _) = rx.recv().unwrap_or_else(|_| {
                panic!("request {i}: reply dropped — workers not joined on drop")
            });
            assert!(matches!(resp, Response::Codes(_)), "request {i}: {resp:?}");
        }
    }

    #[test]
    fn traced_calls_return_traces_and_feed_stage_histograms() {
        let (svc, test) = toy_service(1);
        let q = test.row(0).to_vec();
        // Untraced call: no trace comes back, but stage histograms still
        // record the ladder.
        let plain = svc.call(Request::TopKQuery {
            series: q.clone(),
            k: 3,
            mode: PqQueryMode::Symmetric,
            nprobe: None,
            rerank: Some(8),
        });
        let (traced, trace) = svc.call_traced(
            Request::TopKQuery {
                series: q,
                k: 3,
                mode: PqQueryMode::Symmetric,
                nprobe: None,
                rerank: Some(8),
            },
            true,
        );
        assert_eq!(plain, traced, "tracing must not perturb results");
        let trace = trace.expect("traced call returns a trace");
        assert!(!trace.spans.is_empty());
        assert_eq!(trace.hits.len(), 3, "explanations parallel the hit list");
        let m = svc.shutdown();
        use crate::obs::Stage;
        assert_eq!(m.stage(Stage::BlockedScan).count, 2);
        assert_eq!(m.stage(Stage::Rerank).count, 2);
    }

    #[test]
    fn prometheus_text_is_valid_and_reports_index_header() {
        let (svc, test) = toy_service(1);
        let _ = svc.call(Request::NnQuery {
            series: test.row(1).to_vec(),
            mode: PqQueryMode::Symmetric,
            nprobe: None,
        });
        let text = svc.prometheus_text();
        let samples =
            crate::obs::prometheus::validate_exposition(&text).expect("valid exposition");
        assert!(samples > 10, "expected a substantive document, got {samples}");
        assert!(text.contains("pqdtw_scan_items_scanned_total"));
        assert!(text.contains("pqdtw_index_subspaces 4\n"));
        assert!(text.contains("pqdtw_index_codebook_size 8\n"));
        assert!(text.contains("pqdtw_build_info{version=\""));
        assert!(text.contains("pqdtw_uptime_seconds"));
    }

    #[test]
    fn attached_job_plane_shows_up_in_the_exposition() {
        let tt = ucr_like_by_name("SpikePosition", 43).unwrap();
        let cfg = PqConfig {
            n_subspaces: 4,
            codebook_size: 8,
            window_frac: 0.2,
            ..Default::default()
        };
        let engine = Arc::new(Engine::build(&tt.train, &cfg, 1).unwrap());
        let svc = Service::start(Arc::clone(&engine), ServiceConfig::default());
        let text = svc.prometheus_text();
        assert!(!text.contains("pqdtw_jobs_"), "no job plane attached yet");
        let mgr = crate::jobs::JobManager::start(
            engine,
            Arc::new(crate::obs::log::JsonLogger::disabled()),
            None,
            crate::jobs::JobConfig::default(),
        );
        svc.attach_jobs(Arc::clone(&mgr));
        // Second attach is ignored, not an error.
        svc.attach_jobs(mgr);
        let text = svc.prometheus_text();
        crate::obs::prometheus::validate_exposition(&text).expect("valid exposition");
        assert!(text.contains("pqdtw_jobs_running 0\n"));
        assert!(text.contains("pqdtw_jobs_queued 0\n"));
        assert!(text.contains("pqdtw_jobs_submitted_total{kind=\"all_pairs_topk\"} 0\n"));
    }

    #[test]
    fn external_requests_share_the_metrics_sink() {
        let (svc, _) = toy_service(1);
        svc.record_external(crate::coordinator::metrics::RequestClass::Ping, 3, false);
        let m = svc.shutdown();
        assert_eq!(m.requests, 1);
        assert_eq!(m.class(crate::coordinator::metrics::RequestClass::Ping).requests, 1);
    }

    #[test]
    fn shutdown_rejects_new_requests() {
        let (svc, test) = toy_service(1);
        let q = test.row(0).to_vec();
        let m = svc.shutdown();
        assert_eq!(m.errors, 0);
        // new service needed after shutdown — check a fresh one works
        let (svc2, _) = toy_service(1);
        assert!(matches!(svc2.call(Request::Encode { series: q }), Response::Codes(_)));
    }
}
