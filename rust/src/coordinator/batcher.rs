//! Size-or-deadline dynamic batcher.
//!
//! Requests accumulate until either `max_batch` items are waiting or the
//! oldest item has waited `max_delay` — the same policy a serving router
//! uses to trade latency for throughput. Implemented over a Condvar'd
//! queue; no external runtime (tokio is unavailable in the offline crate
//! set; see DESIGN.md §3).

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Batching policy.
#[derive(Debug, Clone, Copy)]
pub struct BatcherConfig {
    /// Maximum items per batch.
    pub max_batch: usize,
    /// Maximum time the oldest item may wait before the batch is flushed.
    pub max_delay: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 16, max_delay: Duration::from_millis(2) }
    }
}

struct Inner<T> {
    queue: VecDeque<(Instant, T)>,
    closed: bool,
}

/// A thread-safe dynamic batcher.
pub struct DynamicBatcher<T> {
    cfg: BatcherConfig,
    inner: Mutex<Inner<T>>,
    cv: Condvar,
}

impl<T> DynamicBatcher<T> {
    /// New batcher with the given policy.
    pub fn new(cfg: BatcherConfig) -> Self {
        DynamicBatcher {
            cfg,
            inner: Mutex::new(Inner { queue: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
        }
    }

    /// Enqueue one item. Returns `false` if the batcher is closed.
    pub fn push(&self, item: T) -> bool {
        let mut inner = self.inner.lock().unwrap();
        if inner.closed {
            return false;
        }
        inner.queue.push_back((Instant::now(), item));
        self.cv.notify_one();
        true
    }

    /// Blocking: take the next batch. Returns `None` once the batcher is
    /// closed *and* drained.
    ///
    /// Policy: **continuous batching** (vLLM-style). A non-empty queue is
    /// drained immediately (up to `max_batch`); batches larger than one
    /// form naturally while workers are busy, so an idle service adds no
    /// artificial linger latency. `max_delay` only caps the extra wait
    /// when the caller opts into lingering for a fuller batch via
    /// [`DynamicBatcher::next_batch_lingering`].
    pub fn next_batch(&self) -> Option<Vec<T>> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if !inner.queue.is_empty() {
                let take = inner.queue.len().min(self.cfg.max_batch);
                let batch: Vec<T> = inner.queue.drain(..take).map(|(_, it)| it).collect();
                return Some(batch);
            } else if inner.closed {
                return None;
            } else {
                inner = self.cv.wait(inner).unwrap();
            }
        }
    }

    /// Deadline-lingering variant of [`DynamicBatcher::next_batch`]: wait
    /// until the batch is full or the oldest item has aged `max_delay`.
    /// Trades latency for throughput when per-batch fixed costs dominate.
    pub fn next_batch_lingering(&self) -> Option<Vec<T>> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if !inner.queue.is_empty() {
                let oldest = inner.queue.front().unwrap().0;
                let age = oldest.elapsed();
                if inner.queue.len() >= self.cfg.max_batch || age >= self.cfg.max_delay {
                    let take = inner.queue.len().min(self.cfg.max_batch);
                    let batch: Vec<T> =
                        inner.queue.drain(..take).map(|(_, it)| it).collect();
                    return Some(batch);
                }
                let remaining = self.cfg.max_delay - age;
                let (guard, _) = self.cv.wait_timeout(inner, remaining).unwrap();
                inner = guard;
            } else if inner.closed {
                return None;
            } else {
                inner = self.cv.wait(inner).unwrap();
            }
        }
    }

    /// Close the batcher; `next_batch` drains what is left, then `None`.
    pub fn close(&self) {
        let mut inner = self.inner.lock().unwrap();
        inner.closed = true;
        self.cv.notify_all();
    }

    /// Current queue depth (for backpressure decisions / metrics).
    pub fn depth(&self) -> usize {
        self.inner.lock().unwrap().queue.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn full_batch_flushes_immediately() {
        let b = DynamicBatcher::new(BatcherConfig {
            max_batch: 4,
            max_delay: Duration::from_secs(10),
        });
        for i in 0..4 {
            assert!(b.push(i));
        }
        let batch = b.next_batch().unwrap();
        assert_eq!(batch, vec![0, 1, 2, 3]);
    }

    #[test]
    fn continuous_mode_flushes_partial_batch_immediately() {
        let b = DynamicBatcher::new(BatcherConfig {
            max_batch: 100,
            max_delay: Duration::from_secs(10),
        });
        b.push(7u32);
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch, vec![7]);
        // no linger: a lone item must not wait for the deadline
        assert!(t0.elapsed() < Duration::from_millis(5));
    }

    #[test]
    fn lingering_mode_waits_for_deadline() {
        let b = DynamicBatcher::new(BatcherConfig {
            max_batch: 100,
            max_delay: Duration::from_millis(5),
        });
        b.push(7u32);
        let t0 = Instant::now();
        let batch = b.next_batch_lingering().unwrap();
        assert_eq!(batch, vec![7]);
        assert!(t0.elapsed() >= Duration::from_millis(4));
    }

    #[test]
    fn lingering_mode_full_batch_immediate() {
        let b = DynamicBatcher::new(BatcherConfig {
            max_batch: 2,
            max_delay: Duration::from_secs(10),
        });
        b.push(1u32);
        b.push(2u32);
        let t0 = Instant::now();
        assert_eq!(b.next_batch_lingering().unwrap(), vec![1, 2]);
        assert!(t0.elapsed() < Duration::from_millis(5));
    }

    #[test]
    fn close_drains_then_none() {
        let b = DynamicBatcher::new(BatcherConfig {
            max_batch: 2,
            max_delay: Duration::from_millis(1),
        });
        b.push(1);
        b.push(2);
        b.push(3);
        b.close();
        assert!(!b.push(4));
        assert_eq!(b.next_batch().unwrap(), vec![1, 2]);
        assert_eq!(b.next_batch().unwrap(), vec![3]);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn concurrent_producers_consumer() {
        let b = Arc::new(DynamicBatcher::new(BatcherConfig {
            max_batch: 8,
            max_delay: Duration::from_millis(1),
        }));
        let n_producers = 4;
        let per = 50;
        let mut handles = Vec::new();
        for p in 0..n_producers {
            let b = Arc::clone(&b);
            handles.push(std::thread::spawn(move || {
                for i in 0..per {
                    b.push(p * per + i);
                }
            }));
        }
        let consumer = {
            let b = Arc::clone(&b);
            std::thread::spawn(move || {
                let mut seen = Vec::new();
                while let Some(batch) = b.next_batch() {
                    assert!(batch.len() <= 8);
                    seen.extend(batch);
                    if seen.len() == n_producers * per {
                        break;
                    }
                }
                seen
            })
        };
        for h in handles {
            h.join().unwrap();
        }
        let mut seen = consumer.join().unwrap();
        seen.sort_unstable();
        assert_eq!(seen, (0..n_producers * per).collect::<Vec<_>>());
    }

    #[test]
    fn depth_reports_queue() {
        let b = DynamicBatcher::new(BatcherConfig {
            max_batch: 10,
            max_delay: Duration::from_secs(1),
        });
        assert_eq!(b.depth(), 0);
        b.push(1);
        b.push(2);
        assert_eq!(b.depth(), 2);
    }
}
