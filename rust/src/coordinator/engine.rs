//! Engine: the trained state (quantizer + encoded database) and the
//! request vocabulary it serves.

use anyhow::Result;

use crate::core::series::Dataset;
use crate::nn::knn::PqQueryMode;
use crate::pq::distance as pqdist;
use crate::pq::quantizer::{EncodedDataset, PqConfig, ProductQuantizer};

/// A request to the similarity engine.
#[derive(Debug, Clone)]
pub enum Request {
    /// Encode a raw series into a PQ code word.
    Encode {
        /// The raw series (must match the trained length).
        series: Vec<f64>,
    },
    /// 1-NN query against the encoded database.
    NnQuery {
        /// The raw query series.
        series: Vec<f64>,
        /// Symmetric (encode + LUT) or asymmetric (table + LUT).
        mode: PqQueryMode,
    },
    /// Approximate distance between two database items by id.
    PairDist {
        /// First item id.
        i: usize,
        /// Second item id.
        j: usize,
    },
}

/// A response from the engine.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    /// PQ code word.
    Codes(Vec<u16>),
    /// Nearest-neighbour result.
    Nn {
        /// Database index of the nearest item.
        index: usize,
        /// Approximate distance.
        distance: f64,
        /// Label of the nearest item when the database is labeled.
        label: Option<i64>,
    },
    /// Pairwise distance.
    Dist(f64),
    /// Request failed.
    Error(String),
}

/// Trained engine state: quantizer, encoded database, and the raw
/// database retained for asymmetric re-ranking use cases.
pub struct Engine {
    /// Trained product quantizer.
    pub pq: ProductQuantizer,
    /// The encoded database.
    pub encoded: EncodedDataset,
    /// Number of database items.
    pub n_items: usize,
}

impl Engine {
    /// Train a quantizer on `db` and encode it.
    pub fn build(db: &Dataset, cfg: &PqConfig, seed: u64) -> Result<Self> {
        let pq = ProductQuantizer::train(db, cfg, seed)?;
        let encoded = pq.encode_dataset(db);
        Ok(Engine { pq, encoded, n_items: db.n_series() })
    }

    /// Serve one request.
    pub fn handle(&self, req: &Request) -> Response {
        match req {
            Request::Encode { series } => {
                if series.len() != self.pq.series_len {
                    return Response::Error(format!(
                        "series length {} != trained length {}",
                        series.len(),
                        self.pq.series_len
                    ));
                }
                let (codes, _, _) = self.pq.encode(series);
                Response::Codes(codes)
            }
            Request::NnQuery { series, mode } => {
                if series.len() != self.pq.series_len {
                    return Response::Error(format!(
                        "series length {} != trained length {}",
                        series.len(),
                        self.pq.series_len
                    ));
                }
                if self.n_items == 0 {
                    return Response::Error("empty database".into());
                }
                let (best_j, best_sq) = match mode {
                    PqQueryMode::Symmetric => {
                        let (codes, _, _) = self.pq.encode(series);
                        let mut best = (0usize, f64::INFINITY);
                        for j in 0..self.n_items {
                            let d = pqdist::symmetric_sq(
                                &self.pq.codebook,
                                &codes,
                                self.encoded.code(j),
                            );
                            if d < best.1 {
                                best = (j, d);
                            }
                        }
                        best
                    }
                    PqQueryMode::Asymmetric => {
                        let table = self.pq.asymmetric_table(series);
                        let mut best = (0usize, f64::INFINITY);
                        for j in 0..self.n_items {
                            let d = pqdist::asymmetric_sq(
                                &self.pq.codebook,
                                &table,
                                self.encoded.code(j),
                            );
                            if d < best.1 {
                                best = (j, d);
                            }
                        }
                        best
                    }
                };
                Response::Nn {
                    index: best_j,
                    distance: best_sq.sqrt(),
                    label: self.encoded.labels.get(best_j).copied(),
                }
            }
            Request::PairDist { i, j } => {
                if *i >= self.n_items || *j >= self.n_items {
                    return Response::Error("index out of range".into());
                }
                Response::Dist(self.pq.patched_distance(&self.encoded, *i, *j))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::ucr_like::ucr_like_by_name;

    fn toy_engine() -> (Engine, Dataset) {
        let tt = ucr_like_by_name("SpikePosition", 41).unwrap();
        let cfg = PqConfig {
            n_subspaces: 4,
            codebook_size: 16,
            window_frac: 0.2,
            ..Default::default()
        };
        let engine = Engine::build(&tt.train, &cfg, 1).unwrap();
        (engine, tt.test)
    }

    #[test]
    fn encode_request() {
        let (engine, test) = toy_engine();
        match engine.handle(&Request::Encode { series: test.row(0).to_vec() }) {
            Response::Codes(c) => assert_eq!(c.len(), 4),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn nn_query_modes() {
        let (engine, test) = toy_engine();
        for mode in [PqQueryMode::Symmetric, PqQueryMode::Asymmetric] {
            match engine.handle(&Request::NnQuery { series: test.row(0).to_vec(), mode }) {
                Response::Nn { index, distance, label } => {
                    assert!(index < engine.n_items);
                    assert!(distance.is_finite());
                    assert!(label.is_some());
                }
                other => panic!("unexpected {other:?}"),
            }
        }
    }

    #[test]
    fn pair_dist_and_errors() {
        let (engine, _) = toy_engine();
        match engine.handle(&Request::PairDist { i: 0, j: 1 }) {
            Response::Dist(d) => assert!(d >= 0.0),
            other => panic!("unexpected {other:?}"),
        }
        assert!(matches!(
            engine.handle(&Request::PairDist { i: 0, j: 999_999 }),
            Response::Error(_)
        ));
        assert!(matches!(
            engine.handle(&Request::Encode { series: vec![0.0; 3] }),
            Response::Error(_)
        ));
    }
}
