//! Engine: the trained state (quantizer + encoded database + optional
//! IVF index + retained raw series) and the request vocabulary it
//! serves.
//!
//! Serving modes for NN queries form a recall/latency dial:
//!
//! - **exhaustive** — scan every PQ code through the blocked kernel
//!   (query-collapsed LUT + segment-major blocks + pruning cascade,
//!   `docs/DESIGN.md` §6; optionally sharded over `scan_threads` std
//!   threads); exact w.r.t. the PQ approximation.
//! - **IVF-probed** — scan only the `nprobe` nearest coarse cells;
//!   `nprobe = nlist` is bit-identical to the exhaustive scan, smaller
//!   `nprobe` trades recall for latency.
//! - **re-ranked** — rescore the PQ candidate pool with true windowed
//!   DTW against the retained raw database, so returned distances are
//!   exact DTW values, not approximations.

use std::path::Path;
use std::time::Instant;

use anyhow::Result;

use crate::core::series::Dataset;
use crate::nn::ivf::{CoarseMetric, IvfIndex};
use crate::nn::knn::PqQueryMode;
use crate::nn::topk::{rerank_dtw, topk_scan_blocked_stats, Neighbor, QueryLut};
use crate::obs::{HitExplain, QueryTrace, ScanSnapshot, ScanStats, Stage, StageSpan};
use crate::pq::encode::CodeBlocks;
use crate::pq::quantizer::{EncodedDataset, PqConfig, ProductQuantizer};

use super::metrics::RequestClass;

/// A request to the similarity engine.
#[derive(Debug, Clone)]
pub enum Request {
    /// Encode a raw series into a PQ code word.
    Encode {
        /// The raw series (must match the trained length).
        series: Vec<f64>,
    },
    /// 1-NN query against the encoded database.
    NnQuery {
        /// The raw query series.
        series: Vec<f64>,
        /// Symmetric (encode + LUT) or asymmetric (table + LUT).
        mode: PqQueryMode,
        /// Probe only the `n` nearest IVF cells instead of scanning all
        /// items (requires an engine built with an IVF index).
        nprobe: Option<usize>,
    },
    /// Top-k query against the encoded database.
    TopKQuery {
        /// The raw query series.
        series: Vec<f64>,
        /// Number of neighbours to return (`>= 1`; clamped to the
        /// database size).
        k: usize,
        /// Symmetric (encode + LUT) or asymmetric (table + LUT).
        mode: PqQueryMode,
        /// Probe only the `n` nearest IVF cells instead of scanning all
        /// items (requires an engine built with an IVF index).
        nprobe: Option<usize>,
        /// Re-rank: fetch this many PQ candidates (clamped to `>= k`),
        /// rescore them with true windowed DTW against the raw database
        /// and return the `k` best with exact distances.
        rerank: Option<usize>,
    },
    /// Approximate distance between two database items by id.
    PairDist {
        /// First item id.
        i: usize,
        /// Second item id.
        j: usize,
    },
}

impl Request {
    /// Metrics class of this request (the serving mode it exercises).
    pub fn class(&self) -> RequestClass {
        match self {
            Request::Encode { .. } => RequestClass::Encode,
            Request::NnQuery { .. } => RequestClass::Nn,
            Request::PairDist { .. } => RequestClass::PairDist,
            Request::TopKQuery { nprobe, rerank, .. } => match (nprobe, rerank) {
                (_, Some(_)) => RequestClass::TopKReranked,
                (Some(_), None) => RequestClass::TopKProbed,
                (None, None) => RequestClass::TopKExhaustive,
            },
        }
    }
}

/// One ranked neighbour in a [`Response::TopK`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Hit {
    /// Database index of the neighbour.
    pub index: usize,
    /// Distance (PQ-approximate, or exact DTW after a re-rank).
    pub distance: f64,
    /// Label of the neighbour when the database is labeled.
    pub label: Option<i64>,
}

/// A response from the engine.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    /// PQ code word.
    Codes(Vec<u16>),
    /// Nearest-neighbour result.
    Nn {
        /// Database index of the nearest item.
        index: usize,
        /// Approximate distance.
        distance: f64,
        /// Label of the nearest item when the database is labeled.
        label: Option<i64>,
    },
    /// Ranked top-k result, ascending by distance.
    TopK(Vec<Hit>),
    /// Pairwise distance.
    Dist(f64),
    /// Request failed.
    Error(String),
}

/// Trained engine state: quantizer, encoded database, the raw database
/// retained for exact DTW re-ranking, and an optional IVF index for
/// probed scans.
pub struct Engine {
    /// Trained product quantizer.
    pub pq: ProductQuantizer,
    /// The encoded database.
    pub encoded: EncodedDataset,
    /// The raw database (re-rank rescoring and IVF construction).
    pub raw: Dataset,
    /// Optional inverted-file index over the database.
    pub ivf: Option<IvfIndex>,
    /// Number of database items.
    pub n_items: usize,
    /// Blocked segment-major copy of the codes for the scan kernel —
    /// derived from `encoded` on build/open, never persisted
    /// (`docs/DESIGN.md` §6).
    blocks: CodeBlocks,
    /// Threads used for exhaustive top-k scans (1 = sequential).
    scan_threads: usize,
    /// Process-lifetime prune-cascade counters: every query's per-query
    /// sink is merged in here, so the Prometheus exposition can report
    /// cumulative scan/abandon totals.
    scan_stats: ScanStats,
    /// Jobs read from the index file's jobs section on [`Engine::open`]
    /// (empty on [`Engine::build`]). The job plane
    /// ([`crate::jobs::JobManager`]) consumes these on startup to
    /// recover terminal results and re-enqueue interrupted jobs.
    pub recovered_jobs: Vec<crate::jobs::PersistedJob>,
    /// Shard membership when this engine holds a deterministic slice of
    /// a larger database (`build-index --shard i/n`). When set, every
    /// `Nn`/`TopK` hit index is mapped through the global-id table so
    /// results carry database-global indices — the property a
    /// scatter-gather router needs to merge shard answers bit-identically
    /// to the unsharded scan.
    pub shard: Option<crate::store::ShardInfo>,
}

/// Identification summary of the serving state (the index header a
/// remote `stats` call reports): `M`/`K`/`L`, the DTW window fraction,
/// the coarse metric, and the database size.
#[derive(Debug, Clone, PartialEq)]
pub struct EngineInfo {
    /// PQ subspaces (`M`).
    pub n_subspaces: usize,
    /// Codebook size per subspace (`K`).
    pub codebook_size: usize,
    /// Trained series length (`L`).
    pub series_len: usize,
    /// Sakoe-Chiba window fraction of the trained config.
    pub window_frac: f64,
    /// Coarse metric of the IVF index (`"dtw"` / `"euclidean"`), or
    /// `"none"` when no IVF index is attached.
    pub coarse_metric: String,
    /// Number of database items.
    pub n_items: usize,
    /// IVF list count, when an IVF index is attached.
    pub nlist: Option<usize>,
}

impl Engine {
    /// Train a quantizer on `db` and encode it. No IVF index is built;
    /// attach one with [`Engine::enable_ivf`].
    pub fn build(db: &Dataset, cfg: &PqConfig, seed: u64) -> Result<Self> {
        let pq = ProductQuantizer::train(db, cfg, seed)?;
        let encoded = pq.encode_dataset(db);
        let blocks = encoded.to_blocks(pq.codebook.k);
        Ok(Engine {
            pq,
            encoded,
            raw: db.clone(),
            ivf: None,
            n_items: db.n_series(),
            blocks,
            scan_threads: 1,
            scan_stats: ScanStats::new(),
            recovered_jobs: Vec::new(),
            shard: None,
        })
    }

    /// Build shard `shard_index` of an `shard_count`-way deterministic
    /// split: the quantizer is trained on the **full** database (same
    /// seed ⇒ bit-identical codebooks on every shard and on the
    /// unsharded build), then only the rows with
    /// `id % shard_count == shard_index` are encoded and retained.
    /// Because per-item PQ distances depend only on the shared
    /// quantizer and the item's own code, a router that merges the
    /// shards' top-k lists through the `(distance, index)` total order
    /// reproduces the unsharded exhaustive scan bit-for-bit
    /// (`docs/serving-topology.md`).
    pub fn build_shard(
        db: &Dataset,
        cfg: &PqConfig,
        seed: u64,
        shard_index: u64,
        shard_count: u64,
    ) -> Result<Self> {
        anyhow::ensure!(shard_count >= 1, "shard count must be >= 1");
        anyhow::ensure!(
            shard_index < shard_count,
            "shard index {shard_index} out of range for {shard_count} shards"
        );
        let pq = ProductQuantizer::train(db, cfg, seed)?;
        let keep: Vec<usize> = (0..db.n_series())
            .filter(|&id| id as u64 % shard_count == shard_index)
            .collect();
        let raw = db.subset(&keep);
        let encoded = pq.encode_dataset(&raw);
        let blocks = encoded.to_blocks(pq.codebook.k);
        let n_items = raw.n_series();
        Ok(Engine {
            pq,
            encoded,
            raw,
            ivf: None,
            n_items,
            blocks,
            scan_threads: 1,
            scan_stats: ScanStats::new(),
            recovered_jobs: Vec::new(),
            shard: Some(crate::store::ShardInfo {
                shard_index,
                shard_count,
                global_ids: keep.iter().map(|&i| i as u64).collect(),
            }),
        })
    }

    /// Build an IVF index with `nlist` coarse cells over the retained
    /// raw database, enabling `nprobe` requests. The blocked code copy
    /// for the kernel probe path is attached immediately.
    pub fn enable_ivf(&mut self, nlist: usize, metric: CoarseMetric, seed: u64) {
        let mut ivf = IvfIndex::build(&self.raw, nlist, metric, seed);
        ivf.attach_blocks(&self.encoded, self.pq.codebook.k);
        self.ivf = Some(ivf);
    }

    /// Persist the full serving state — quantizer, encoded database,
    /// raw database, optional IVF index — to a versioned index file
    /// (see [`crate::store`] and `docs/index-format.md`).
    pub fn save(&self, path: &Path) -> Result<()> {
        crate::store::save_index_full(
            path,
            &self.pq,
            &self.encoded,
            &self.raw,
            self.ivf.as_ref(),
            &[],
            self.shard.as_ref(),
        )
    }

    /// Reopen a saved index without retraining. The loaded engine
    /// answers every request bit-identically to the engine that was
    /// saved (scan threads reset to 1 — call
    /// [`Engine::set_scan_threads`] to re-shard). The kernel's blocked
    /// code layouts are derived state and rebuilt here from the
    /// persisted row-major codes — the on-disk format is unchanged.
    pub fn open(path: &Path) -> Result<Self> {
        let idx = crate::store::load_index(path)?;
        let n_items = idx.encoded.n();
        let blocks = idx.encoded.to_blocks(idx.pq.codebook.k);
        let mut ivf = idx.ivf;
        if let Some(ivf) = ivf.as_mut() {
            ivf.attach_blocks(&idx.encoded, idx.pq.codebook.k);
        }
        Ok(Engine {
            pq: idx.pq,
            encoded: idx.encoded,
            raw: idx.raw,
            ivf,
            n_items,
            blocks,
            scan_threads: 1,
            scan_stats: ScanStats::new(),
            recovered_jobs: idx.jobs,
            shard: idx.shard,
        })
    }

    /// Shard exhaustive top-k scans over `n` threads (1 = sequential).
    ///
    /// Threads are spawned per query (no pool in the offline crate set),
    /// which costs tens of µs per request — worthwhile only when the
    /// database is large enough that the scan dominates that overhead
    /// (see `benches/perf_hotpath.rs` for the crossover).
    pub fn set_scan_threads(&mut self, n: usize) {
        self.scan_threads = n.max(1);
    }

    /// Warping window for full-length DTW derived from the trained
    /// config's window fraction (used by the re-rank stage and as the
    /// natural coarse-DTW window).
    pub fn full_window(&self) -> Option<usize> {
        let frac = self.pq.config.window_frac;
        if frac >= 1.0 {
            None
        } else {
            Some(((frac * self.raw.len as f64).ceil() as usize).max(1))
        }
    }

    /// Cumulative prune-cascade counters over the engine's lifetime
    /// (every served query merges its per-query sink in here).
    pub fn scan_stats(&self) -> ScanSnapshot {
        self.scan_stats.snapshot()
    }

    /// Identification summary of the serving state.
    pub fn info(&self) -> EngineInfo {
        let coarse_metric = match self.ivf.as_ref().map(|ivf| ivf.coarse_metric()) {
            Some(CoarseMetric::Dtw { .. }) => "dtw".to_string(),
            Some(CoarseMetric::Euclidean) => "euclidean".to_string(),
            None => "none".to_string(),
        };
        EngineInfo {
            n_subspaces: self.encoded.n_subspaces,
            codebook_size: self.pq.codebook.k,
            series_len: self.pq.series_len,
            window_frac: self.pq.config.window_frac,
            coarse_metric,
            n_items: self.n_items,
            nlist: self.ivf.as_ref().map(|ivf| ivf.nlist()),
        }
    }

    /// Walk one query down the stage ladder (`lut_collapse` →
    /// `coarse_probe` → `blocked_scan` → `rerank`), recording a span per
    /// stage into `trace` and kernel counters into the per-query sink.
    /// Returns the ranked neighbours — bit-identical to the pre-trace
    /// code path: the ladder calls the same kernels with the same
    /// arguments, tracing only observes.
    #[allow(clippy::too_many_arguments)]
    fn query_ladder(
        &self,
        series: &[f64],
        k: usize,
        depth: usize,
        mode: PqQueryMode,
        nprobe: Option<usize>,
        rerank: bool,
        explain: bool,
        trace: &mut QueryTrace,
    ) -> std::result::Result<Vec<Neighbor>, Response> {
        let qstats = ScanStats::new();
        let n_items = self.n_items as u64;
        let cands = match nprobe {
            Some(np) => {
                let Some(ivf) = &self.ivf else {
                    return Err(Response::Error(
                        "nprobe set but the engine has no IVF index (call enable_ivf)".into(),
                    ));
                };
                let t0 = Instant::now();
                let lut = QueryLut::build(&self.pq, series, mode);
                let lut_us = t0.elapsed().as_micros() as u64;
                trace.spans.push(StageSpan {
                    stage: Stage::LutCollapse,
                    wall_us: lut_us,
                    candidates_in: n_items,
                    candidates_out: n_items,
                });
                let t1 = Instant::now();
                let (cands, probe) = ivf.query_topk_traced(
                    &self.pq,
                    &self.encoded,
                    &lut,
                    series,
                    depth,
                    np,
                    Some(&qstats),
                );
                let total_us = t1.elapsed().as_micros() as u64;
                trace.spans.push(StageSpan {
                    stage: Stage::CoarseProbe,
                    wall_us: probe.probe_us,
                    candidates_in: n_items,
                    candidates_out: probe.items_in_cells,
                });
                let s = qstats.snapshot();
                trace.spans.push(StageSpan {
                    stage: Stage::BlockedScan,
                    wall_us: total_us.saturating_sub(probe.probe_us),
                    candidates_in: s.items_scanned,
                    candidates_out: s.items_scanned - s.items_abandoned,
                });
                cands
            }
            None => {
                let t0 = Instant::now();
                let lut = QueryLut::build(&self.pq, series, mode);
                let clut = lut.collapse(&self.pq.codebook);
                if matches!(mode, PqQueryMode::Symmetric) {
                    qstats.add_lut_collapse();
                }
                let lut_us = t0.elapsed().as_micros() as u64;
                trace.spans.push(StageSpan {
                    stage: Stage::LutCollapse,
                    wall_us: lut_us,
                    candidates_in: n_items,
                    candidates_out: n_items,
                });
                let t1 = Instant::now();
                let cands = topk_scan_blocked_stats(
                    &self.blocks,
                    &clut,
                    depth,
                    self.scan_threads,
                    true,
                    Some(&qstats),
                );
                let scan_us = t1.elapsed().as_micros() as u64;
                let s = qstats.snapshot();
                trace.spans.push(StageSpan {
                    stage: Stage::BlockedScan,
                    wall_us: scan_us,
                    candidates_in: s.items_scanned,
                    candidates_out: s.items_scanned - s.items_abandoned,
                });
                cands
            }
        };
        let ranked = if rerank {
            let t2 = Instant::now();
            let ranked = rerank_dtw(&self.raw, series, &cands, k, self.full_window());
            trace.spans.push(StageSpan {
                stage: Stage::Rerank,
                wall_us: t2.elapsed().as_micros() as u64,
                candidates_in: cands.len() as u64,
                candidates_out: ranked.len() as u64,
            });
            ranked
        } else {
            cands.clone()
        };
        if explain {
            trace.hits = ranked
                .iter()
                .map(|n| {
                    let (pq_estimate, exact_dtw, admitted_by) = if rerank {
                        let est = cands
                            .iter()
                            .find(|c| c.index == n.index)
                            .map(|c| c.distance)
                            .unwrap_or(f64::NAN);
                        (est, Some(n.distance), Stage::Rerank)
                    } else {
                        (n.distance, None, Stage::BlockedScan)
                    };
                    HitExplain {
                        index: self.global_index(n.index) as u64,
                        pq_estimate,
                        exact_dtw,
                        admitted_by,
                        shard: None,
                    }
                })
                .collect();
        }
        trace.scan = qstats.snapshot();
        qstats.merge_into(&self.scan_stats);
        Ok(ranked)
    }

    /// Database-global index of local row `local`: the identity when
    /// unsharded, the shard's global-id table entry otherwise. The
    /// table is strictly increasing (store-validated), so local
    /// tie-break order equals global tie-break order.
    fn global_index(&self, local: usize) -> usize {
        match &self.shard {
            Some(s) => s
                .global_ids
                .get(local)
                .and_then(|&g| usize::try_from(g).ok())
                .unwrap_or(local),
            None => local,
        }
    }

    fn hit(&self, n: Neighbor) -> Hit {
        Hit {
            index: self.global_index(n.index),
            distance: n.distance,
            label: self.encoded.labels.get(n.index).copied(),
        }
    }

    /// Serve one request.
    pub fn handle(&self, req: &Request) -> Response {
        self.handle_traced(req, false).0
    }

    /// Serve one request and record its [`QueryTrace`] (the stage
    /// ladder runs for the query classes `NnQuery`/`TopKQuery`; other
    /// classes return `None`). With `explain` set, the trace also
    /// carries per-hit [`HitExplain`] records. The response is
    /// bit-identical to [`Engine::handle`]: tracing only observes.
    ///
    /// The trace's `request_id` is left at 0 — the network server
    /// stamps the client-supplied id over it.
    pub fn handle_traced(&self, req: &Request, explain: bool) -> (Response, Option<QueryTrace>) {
        match req {
            Request::Encode { series } => {
                if series.len() != self.pq.series_len {
                    return (
                        Response::Error(format!(
                            "series length {} != trained length {}",
                            series.len(),
                            self.pq.series_len
                        )),
                        None,
                    );
                }
                let (codes, _, _) = self.pq.encode(series);
                (Response::Codes(codes), None)
            }
            Request::NnQuery { series, mode, nprobe } => {
                if series.len() != self.pq.series_len {
                    return (
                        Response::Error(format!(
                            "series length {} != trained length {}",
                            series.len(),
                            self.pq.series_len
                        )),
                        None,
                    );
                }
                if self.n_items == 0 {
                    return (Response::Error("empty database".into()), None);
                }
                let mut trace = QueryTrace::default();
                match self.query_ladder(series, 1, 1, *mode, *nprobe, false, explain, &mut trace)
                {
                    Err(resp) => (resp, None),
                    Ok(hits) => match hits.first() {
                        Some(&n) => {
                            let h = self.hit(n);
                            (
                                Response::Nn {
                                    index: h.index,
                                    distance: h.distance,
                                    label: h.label,
                                },
                                Some(trace),
                            )
                        }
                        None => {
                            (Response::Error("probed cells were empty".into()), Some(trace))
                        }
                    },
                }
            }
            Request::TopKQuery { series, k, mode, nprobe, rerank } => {
                if series.len() != self.pq.series_len {
                    return (
                        Response::Error(format!(
                            "series length {} != trained length {}",
                            series.len(),
                            self.pq.series_len
                        )),
                        None,
                    );
                }
                if self.n_items == 0 {
                    return (Response::Error("empty database".into()), None);
                }
                if *k == 0 {
                    return (Response::Error("k must be >= 1".into()), None);
                }
                let k = (*k).min(self.n_items);
                // candidate depth: k, widened when a re-rank follows
                let depth = match rerank {
                    Some(r) => (*r).max(k).min(self.n_items),
                    None => k,
                };
                let mut trace = QueryTrace::default();
                match self.query_ladder(
                    series,
                    k,
                    depth,
                    *mode,
                    *nprobe,
                    rerank.is_some(),
                    explain,
                    &mut trace,
                ) {
                    Err(resp) => (resp, None),
                    Ok(ranked) => (
                        Response::TopK(ranked.into_iter().map(|n| self.hit(n)).collect()),
                        Some(trace),
                    ),
                }
            }
            Request::PairDist { i, j } => {
                if *i >= self.n_items || *j >= self.n_items {
                    return (Response::Error("index out of range".into()), None);
                }
                (Response::Dist(self.pq.patched_distance(&self.encoded, *i, *j)), None)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::ucr_like::ucr_like_by_name;

    fn toy_engine() -> (Engine, Dataset) {
        let tt = ucr_like_by_name("SpikePosition", 41).unwrap();
        let cfg = PqConfig {
            n_subspaces: 4,
            codebook_size: 16,
            window_frac: 0.2,
            ..Default::default()
        };
        let engine = Engine::build(&tt.train, &cfg, 1).unwrap();
        (engine, tt.test)
    }

    #[test]
    fn encode_request() {
        let (engine, test) = toy_engine();
        match engine.handle(&Request::Encode { series: test.row(0).to_vec() }) {
            Response::Codes(c) => assert_eq!(c.len(), 4),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn nn_query_modes() {
        let (engine, test) = toy_engine();
        for mode in [PqQueryMode::Symmetric, PqQueryMode::Asymmetric] {
            match engine.handle(&Request::NnQuery {
                series: test.row(0).to_vec(),
                mode,
                nprobe: None,
            }) {
                Response::Nn { index, distance, label } => {
                    assert!(index < engine.n_items);
                    assert!(distance.is_finite());
                    assert!(label.is_some());
                }
                other => panic!("unexpected {other:?}"),
            }
        }
    }

    #[test]
    fn topk_exhaustive_matches_nn_at_k1() {
        let (mut engine, test) = toy_engine();
        engine.set_scan_threads(2);
        for i in 0..5 {
            let q = test.row(i).to_vec();
            let nn = engine.handle(&Request::NnQuery {
                series: q.clone(),
                mode: PqQueryMode::Asymmetric,
                nprobe: None,
            });
            let topk = engine.handle(&Request::TopKQuery {
                series: q,
                k: 1,
                mode: PqQueryMode::Asymmetric,
                nprobe: None,
                rerank: None,
            });
            match (nn, topk) {
                (Response::Nn { index, distance, .. }, Response::TopK(hits)) => {
                    assert_eq!(hits.len(), 1);
                    assert_eq!(hits[0].index, index);
                    assert_eq!(hits[0].distance, distance);
                }
                other => panic!("unexpected {other:?}"),
            }
        }
    }

    #[test]
    fn topk_probed_full_matches_exhaustive_bitwise() {
        let (mut engine, test) = toy_engine();
        engine.enable_ivf(6, CoarseMetric::Dtw { window: engine.full_window() }, 5);
        let nlist = engine.ivf.as_ref().unwrap().nlist();
        for i in 0..5 {
            let q = test.row(i).to_vec();
            let exhaustive = engine.handle(&Request::TopKQuery {
                series: q.clone(),
                k: 7,
                mode: PqQueryMode::Asymmetric,
                nprobe: None,
                rerank: None,
            });
            let probed = engine.handle(&Request::TopKQuery {
                series: q,
                k: 7,
                mode: PqQueryMode::Asymmetric,
                nprobe: Some(nlist),
                rerank: None,
            });
            assert_eq!(exhaustive, probed, "query {i}");
            assert!(matches!(exhaustive, Response::TopK(ref h) if h.len() == 7));
        }
    }

    #[test]
    fn topk_reranked_returns_true_dtw() {
        use crate::distance::dtw::dtw_sq;
        let (engine, test) = toy_engine();
        let q = test.row(1).to_vec();
        match engine.handle(&Request::TopKQuery {
            series: q.clone(),
            k: 3,
            mode: PqQueryMode::Asymmetric,
            nprobe: None,
            rerank: Some(15),
        }) {
            Response::TopK(hits) => {
                assert_eq!(hits.len(), 3);
                for h in &hits {
                    let want = dtw_sq(&q, engine.raw.row(h.index), engine.full_window()).sqrt();
                    assert!(
                        (h.distance - want).abs() < 1e-9,
                        "index {}: {} vs {}",
                        h.index,
                        h.distance,
                        want
                    );
                }
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn request_classes_reflect_serving_mode() {
        let q = vec![0.0; 4];
        let base = Request::TopKQuery {
            series: q.clone(),
            k: 1,
            mode: PqQueryMode::Symmetric,
            nprobe: None,
            rerank: None,
        };
        assert_eq!(base.class(), RequestClass::TopKExhaustive);
        let probed = Request::TopKQuery {
            series: q.clone(),
            k: 1,
            mode: PqQueryMode::Symmetric,
            nprobe: Some(2),
            rerank: None,
        };
        assert_eq!(probed.class(), RequestClass::TopKProbed);
        let reranked = Request::TopKQuery {
            series: q.clone(),
            k: 1,
            mode: PqQueryMode::Symmetric,
            nprobe: Some(2),
            rerank: Some(8),
        };
        assert_eq!(reranked.class(), RequestClass::TopKReranked);
        assert_eq!(
            Request::NnQuery { series: q, mode: PqQueryMode::Symmetric, nprobe: None }.class(),
            RequestClass::Nn
        );
    }

    #[test]
    fn probe_without_ivf_is_an_error() {
        let (engine, test) = toy_engine();
        assert!(matches!(
            engine.handle(&Request::TopKQuery {
                series: test.row(0).to_vec(),
                k: 2,
                mode: PqQueryMode::Asymmetric,
                nprobe: Some(4),
                rerank: None,
            }),
            Response::Error(_)
        ));
    }

    #[test]
    fn save_open_roundtrip_is_bit_identical() {
        let (mut engine, test) = toy_engine();
        engine.enable_ivf(5, CoarseMetric::Dtw { window: engine.full_window() }, 9);
        let nlist = engine.ivf.as_ref().unwrap().nlist();
        let dir = crate::testutil::unique_temp_dir("engine_store");
        let path = dir.join("index.pqx");
        engine.save(&path).unwrap();
        let reopened = Engine::open(&path).unwrap();
        assert_eq!(reopened.n_items, engine.n_items);
        for i in 0..5 {
            let q = test.row(i).to_vec();
            for req in [
                Request::NnQuery {
                    series: q.clone(),
                    mode: PqQueryMode::Asymmetric,
                    nprobe: None,
                },
                Request::TopKQuery {
                    series: q.clone(),
                    k: 4,
                    mode: PqQueryMode::Asymmetric,
                    nprobe: None,
                    rerank: None,
                },
                Request::TopKQuery {
                    series: q.clone(),
                    k: 4,
                    mode: PqQueryMode::Symmetric,
                    nprobe: Some(nlist),
                    rerank: None,
                },
                Request::TopKQuery {
                    series: q,
                    k: 3,
                    mode: PqQueryMode::Asymmetric,
                    nprobe: Some(2),
                    rerank: Some(9),
                },
            ] {
                assert_eq!(engine.handle(&req), reopened.handle(&req), "query {i}");
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn open_rejects_missing_and_garbage_files() {
        let dir = crate::testutil::unique_temp_dir("engine_store_bad");
        assert!(Engine::open(&dir.join("missing.pqx")).is_err());
        let garbage = dir.join("garbage.pqx");
        std::fs::write(&garbage, b"definitely not an index").unwrap();
        assert!(Engine::open(&garbage).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn traced_responses_are_bit_identical_with_consistent_spans() {
        use crate::obs::Stage;
        let (mut engine, test) = toy_engine();
        engine.enable_ivf(6, CoarseMetric::Dtw { window: engine.full_window() }, 5);
        let nlist = engine.ivf.as_ref().unwrap().nlist();
        let q = test.row(0).to_vec();
        let cases = [
            (None, None),
            (Some(nlist), None),
            (Some(2), None),
            (None, Some(12)),
            (Some(3), Some(9)),
        ];
        for (nprobe, rerank) in cases {
            let req = Request::TopKQuery {
                series: q.clone(),
                k: 4,
                mode: PqQueryMode::Asymmetric,
                nprobe,
                rerank,
            };
            let plain = engine.handle(&req);
            let (traced, trace) = engine.handle_traced(&req, true);
            assert_eq!(plain, traced, "nprobe={nprobe:?} rerank={rerank:?}");
            let trace = trace.expect("query classes carry a trace");
            // Ladder shape: lut_collapse always; coarse_probe iff probed;
            // rerank iff requested.
            assert!(trace.span(Stage::LutCollapse).is_some());
            assert!(trace.span(Stage::BlockedScan).is_some());
            assert_eq!(trace.span(Stage::CoarseProbe).is_some(), nprobe.is_some());
            assert_eq!(trace.span(Stage::Rerank).is_some(), rerank.is_some());
            // Conservation: in − abandoned = out on the scan span.
            let scan = trace.span(Stage::BlockedScan).unwrap();
            assert_eq!(
                scan.candidates_in - trace.scan.items_abandoned,
                scan.candidates_out
            );
            assert_eq!(scan.candidates_in, trace.scan.items_scanned);
            // Explain records mirror the hit list.
            match &traced {
                Response::TopK(hits) => {
                    assert_eq!(trace.hits.len(), hits.len());
                    for (e, h) in trace.hits.iter().zip(hits) {
                        assert_eq!(e.index, h.index as u64);
                        if rerank.is_some() {
                            assert_eq!(e.admitted_by, Stage::Rerank);
                            assert_eq!(e.exact_dtw, Some(h.distance));
                            assert!(e.pq_estimate.is_finite());
                        } else {
                            assert_eq!(e.admitted_by, Stage::BlockedScan);
                            assert_eq!(e.pq_estimate, h.distance);
                            assert_eq!(e.exact_dtw, None);
                        }
                    }
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        // The engine-wide sink accumulated every query's counters.
        let total = engine.scan_stats();
        assert!(total.items_scanned > 0);
    }

    #[test]
    fn untraced_handle_does_not_build_explanations() {
        let (engine, test) = toy_engine();
        let req = Request::TopKQuery {
            series: test.row(0).to_vec(),
            k: 2,
            mode: PqQueryMode::Symmetric,
            nprobe: None,
            rerank: None,
        };
        let (_, trace) = engine.handle_traced(&req, false);
        let trace = trace.unwrap();
        assert!(trace.hits.is_empty());
        assert!(!trace.spans.is_empty());
        // Symmetric exhaustive queries collapse the LUT once.
        assert_eq!(trace.scan.lut_collapses, 1);
    }

    #[test]
    fn engine_info_reports_index_header_summary() {
        let (mut engine, _) = toy_engine();
        let info = engine.info();
        assert_eq!(info.n_subspaces, 4);
        assert_eq!(info.codebook_size, 16);
        assert_eq!(info.series_len, engine.pq.series_len);
        assert!((info.window_frac - 0.2).abs() < 1e-12);
        assert_eq!(info.coarse_metric, "none");
        assert_eq!(info.n_items, engine.n_items);
        assert_eq!(info.nlist, None);
        engine.enable_ivf(6, CoarseMetric::Euclidean, 3);
        let info = engine.info();
        assert_eq!(info.coarse_metric, "euclidean");
        assert_eq!(info.nlist, Some(engine.ivf.as_ref().unwrap().nlist()));
    }

    #[test]
    fn pair_dist_and_errors() {
        let (engine, _) = toy_engine();
        match engine.handle(&Request::PairDist { i: 0, j: 1 }) {
            Response::Dist(d) => assert!(d >= 0.0),
            other => panic!("unexpected {other:?}"),
        }
        assert!(matches!(
            engine.handle(&Request::PairDist { i: 0, j: 999_999 }),
            Response::Error(_)
        ));
        assert!(matches!(
            engine.handle(&Request::Encode { series: vec![0.0; 3] }),
            Response::Error(_)
        ));
        assert!(matches!(
            engine.handle(&Request::TopKQuery {
                series: vec![0.0; 3],
                k: 0,
                mode: PqQueryMode::Symmetric,
                nprobe: None,
                rerank: None,
            }),
            Response::Error(_)
        ));
    }
}
