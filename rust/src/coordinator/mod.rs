//! The serving layer: an in-memory time-series similarity engine with a
//! threaded worker pool, dynamic batching and metrics.
//!
//! The paper's contribution is an algorithm, so per the architecture rule
//! this layer is a driver in the spirit of a model-serving router: it owns
//! the trained quantizer state, accepts concurrent encode / 1-NN / distance
//! requests, groups them through a size-or-deadline dynamic batcher and
//! executes them on a pool of workers, recording latency and batch-size
//! metrics. Python is never on this path.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod service;

pub use batcher::{BatcherConfig, DynamicBatcher};
pub use engine::{Engine, Request, Response};
pub use metrics::{Metrics, MetricsSnapshot};
pub use service::{Service, ServiceConfig};
