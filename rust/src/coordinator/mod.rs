//! The serving layer: an in-memory time-series similarity engine with a
//! threaded worker pool, dynamic batching and metrics.
//!
//! The paper's contribution is an algorithm, so per the architecture rule
//! this layer is a driver in the spirit of a model-serving router: it owns
//! the trained quantizer state, accepts concurrent encode / 1-NN / top-k /
//! distance requests, groups them through a size-or-deadline dynamic
//! batcher and executes them on a pool of workers, recording latency and
//! batch-size metrics per serving mode. Python is never on this path.
//!
//! Top-k queries expose a recall/latency dial: an exhaustive (optionally
//! multi-threaded) scan over all PQ codes, an IVF-probed scan over the
//! `nprobe` nearest coarse cells (`nprobe = nlist` reproduces the
//! exhaustive result bit-for-bit), and an exact re-rank stage that
//! rescores the PQ candidates with true windowed DTW against the raw
//! database.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod service;

pub use batcher::{BatcherConfig, DynamicBatcher};
pub use engine::{Engine, EngineInfo, Hit, Request, Response};
pub use metrics::{
    histogram_percentile, ClassSnapshot, Metrics, MetricsSnapshot, RequestClass, StageSnapshot,
    BUCKETS_US,
};
pub use service::{Service, ServiceConfig};
