//! PJRT client wrapper (feature `pjrt`): compile HLO-text artifacts once,
//! execute many times. Adapted from /opt/xla-example/load_hlo.rs.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

/// A PJRT CPU client plus a cache of compiled executables keyed by
/// artifact file name.
pub struct PjrtRunner {
    client: xla::PjRtClient,
    compiled: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl PjrtRunner {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(PjrtRunner { client, compiled: HashMap::new() })
    }

    /// Platform string (e.g. "cpu"), for logs.
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text file, memoized by its file name.
    pub fn load(&mut self, path: &Path) -> Result<&xla::PjRtLoadedExecutable> {
        let key = path
            .file_name()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_else(|| path.display().to_string());
        if !self.compiled.contains_key(&key) {
            let proto = xla::HloModuleProto::from_text_file(path)
                .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {}: {e:?}", path.display()))?;
            self.compiled.insert(key.clone(), exe);
        }
        Ok(&self.compiled[&key])
    }

    /// Execute a compiled artifact on f32 inputs (each `(data, dims)`),
    /// returning the flat output literals of the result tuple.
    pub fn run_f32(
        &mut self,
        path: &Path,
        inputs: &[(&[f32], &[i64])],
    ) -> Result<Vec<xla::Literal>> {
        let exe = self.load(path)?;
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, dims) in inputs {
            let lit = xla::Literal::vec1(data)
                .reshape(dims)
                .map_err(|e| anyhow!("reshape {dims:?}: {e:?}"))?;
            literals.push(lit);
        }
        Self::exec(exe, &literals)
    }

    /// Execute with pre-built literals (mixed dtypes).
    pub fn run_literals(&mut self, path: &Path, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.load(path)?;
        Self::exec(exe, inputs)
    }

    fn exec(exe: &xla::PjRtLoadedExecutable, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        // aot.py lowers with return_tuple=True: unpack the tuple.
        result.to_tuple().map_err(|e| anyhow!("to_tuple: {e:?}")).context("unpacking result")
    }

    /// Build an i32 literal of the given shape (for code matrices).
    pub fn literal_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
        xla::Literal::vec1(data)
            .reshape(dims)
            .map_err(|e| anyhow!("reshape {dims:?}: {e:?}"))
    }
}
