//! PJRT-backed subspace encoder (feature `pjrt`): runs the AOT-compiled
//! `encode_series` graph as an alternative backend to the native Rust
//! encoder, proving the three layers compose. The Rust side still owns
//! segmentation/pre-alignment (O(D) preprocessing).

use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use super::artifacts::Manifest;
use super::client::PjrtRunner;
use crate::pq::quantizer::ProductQuantizer;

/// Encoder that executes the lowered JAX/Pallas encode graph via PJRT.
pub struct PjrtEncoder {
    runner: PjrtRunner,
    encode_path: PathBuf,
    /// Codebook flattened to f32 once (the graph takes it as an input so
    /// one artifact serves any trained codebook of the same shape).
    codebook_f32: Vec<f32>,
    m: usize,
    k: usize,
    l: usize,
}

impl PjrtEncoder {
    /// Build an encoder for a trained quantizer from the artifact set in
    /// `dir`. Fails when no artifact matches the quantizer's shape.
    pub fn new(pq: &ProductQuantizer, manifest: &Manifest) -> Result<Self> {
        let (m, k, l) = (pq.codebook.n_subspaces, pq.codebook.k, pq.codebook.sub_len);
        let window = pq.codebook.window.unwrap_or(l);
        let spec = manifest.find_encode(m, k, l, window).with_context(|| {
            format!("no encode artifact for (M={m}, K={k}, L={l}, w={window}); rerun `make artifacts` with this variant in aot.py")
        })?;
        let encode_path = manifest.path_of(spec);
        if !encode_path.exists() {
            bail!("artifact file missing: {}", encode_path.display());
        }
        let codebook_f32: Vec<f32> = pq.codebook.centroids.iter().map(|&v| v as f32).collect();
        Ok(PjrtEncoder {
            runner: PjrtRunner::cpu()?,
            encode_path,
            codebook_f32,
            m,
            k,
            l,
        })
    }

    /// Encode one series: segment natively, run the PJRT graph, return
    /// the code word.
    pub fn encode(&mut self, pq: &ProductQuantizer, x: &[f64]) -> Result<Vec<u16>> {
        let subs = pq.segment(x);
        let mut subs_f32 = Vec::with_capacity(self.m * self.l);
        for s in &subs {
            subs_f32.extend(s.iter().map(|&v| v as f32));
        }
        let outputs = self.runner.run_f32(
            &self.encode_path,
            &[
                (&subs_f32, &[self.m as i64, self.l as i64]),
                (&self.codebook_f32, &[self.m as i64, self.k as i64, self.l as i64]),
            ],
        )?;
        if outputs.len() != 2 {
            bail!("encode graph returned {} outputs, expected 2", outputs.len());
        }
        let codes: Vec<i32> = outputs[0]
            .to_vec()
            .map_err(|e| anyhow::anyhow!("codes literal: {e:?}"))?;
        Ok(codes.into_iter().map(|c| c as u16).collect())
    }

    /// Shape tag for logs.
    pub fn shape(&self) -> (usize, usize, usize) {
        (self.m, self.k, self.l)
    }
}
