//! Artifact manifest: what `python/compile/aot.py` produced and where.
//!
//! The manifest is a TSV (`kind\tp1\tp2\tp3\tp4\tfile`) rather than JSON
//! so the default build needs no serialization dependency (the offline
//! registry carries none).

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// What a lowered graph computes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArtifactKind {
    /// `encode_series`: (M, L) subspaces + (M, K, L) codebooks →
    /// codes (M,) i32 + dist_sq (M,) f32.
    Encode,
    /// `adc_table`: (M, L) + (M, K, L) → (M, K) f32.
    Adc,
    /// `pairwise_symmetric`: (N, M) i32 + (P, M) i32 + (M, K, K) f32 →
    /// (N, P) f32.
    PairSym,
}

/// One artifact entry.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactSpec {
    /// Graph kind.
    pub kind: ArtifactKind,
    /// For Encode/Adc: `(M, K, L, window)`. For PairSym: `(N, P, M, K)`.
    pub params: (usize, usize, usize, usize),
    /// HLO text file, relative to the artifact directory.
    pub file: String,
}

/// Parsed manifest plus its base directory.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Artifact directory.
    pub dir: PathBuf,
    /// Entries.
    pub specs: Vec<ArtifactSpec>,
}

impl Manifest {
    /// Load `<dir>/manifest.tsv`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let mut specs = Vec::new();
        for (ln, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let fields: Vec<&str> = line.split('\t').collect();
            if fields.len() != 6 {
                bail!("{}:{}: expected 6 fields, got {}", path.display(), ln + 1, fields.len());
            }
            let kind = match fields[0] {
                "encode" => ArtifactKind::Encode,
                "adc" => ArtifactKind::Adc,
                "pairsym" => ArtifactKind::PairSym,
                other => bail!("{}:{}: unknown kind {other}", path.display(), ln + 1),
            };
            let p = |i: usize| -> Result<usize> {
                fields[i]
                    .parse()
                    .with_context(|| format!("{}:{}: bad int", path.display(), ln + 1))
            };
            specs.push(ArtifactSpec {
                kind,
                params: (p(1)?, p(2)?, p(3)?, p(4)?),
                file: fields[5].to_string(),
            });
        }
        Ok(Manifest { dir: dir.to_path_buf(), specs })
    }

    /// Find an encode artifact for `(m, k, l, window)`.
    pub fn find_encode(&self, m: usize, k: usize, l: usize, window: usize) -> Option<&ArtifactSpec> {
        self.specs
            .iter()
            .find(|s| s.kind == ArtifactKind::Encode && s.params == (m, k, l, window))
    }

    /// Find an ADC artifact for `(m, k, l, window)`.
    pub fn find_adc(&self, m: usize, k: usize, l: usize, window: usize) -> Option<&ArtifactSpec> {
        self.specs
            .iter()
            .find(|s| s.kind == ArtifactKind::Adc && s.params == (m, k, l, window))
    }

    /// Absolute path of a spec's HLO file.
    pub fn path_of(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }

    /// The default artifact directory (`$PQDTW_ARTIFACTS` or `artifacts/`
    /// next to the current directory).
    pub fn default_dir() -> PathBuf {
        std::env::var("PQDTW_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(content: &str) -> PathBuf {
        // Each call gets its own directory: `parses_manifest` and
        // `rejects_malformed` run concurrently in one test process and
        // previously clobbered a shared `pqdtw_manifest_{pid}` dir.
        let dir = crate::testutil::unique_temp_dir("manifest");
        std::fs::write(dir.join("manifest.tsv"), content).unwrap();
        dir
    }

    #[test]
    fn parses_manifest() {
        let dir = write_manifest(
            "encode\t4\t16\t25\t5\tencode_a.hlo.txt\nadc\t4\t16\t25\t5\tadc_a.hlo.txt\npairsym\t8\t64\t4\t16\tp.hlo.txt\n",
        );
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.specs.len(), 3);
        let e = m.find_encode(4, 16, 25, 5).unwrap();
        assert_eq!(e.file, "encode_a.hlo.txt");
        assert!(m.find_encode(4, 16, 25, 6).is_none());
        assert!(m.find_adc(4, 16, 25, 5).is_some());
        assert!(m.path_of(e).ends_with("encode_a.hlo.txt"));
    }

    #[test]
    fn rejects_malformed() {
        let dir = write_manifest("encode\t4\t16\n");
        assert!(Manifest::load(&dir).is_err());
        let dir = write_manifest("what\t1\t2\t3\t4\tf\n");
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn real_artifacts_manifest_if_present() {
        // When `make artifacts` has run, the real manifest must parse.
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.tsv").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(!m.specs.is_empty());
            for s in &m.specs {
                assert!(m.path_of(s).exists(), "{} missing", s.file);
            }
        }
    }
}
