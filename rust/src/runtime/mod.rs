//! Runtime: loads the HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the PJRT CPU client from
//! the request path — the AOT bridge of the three-layer architecture.
//!
//! The artifact manifest ([`artifacts`]) parses without any heavyweight
//! dependency; the PJRT client wrapper and the encoder backend are gated
//! behind the `pjrt` feature so the default build (and CI test loop)
//! stays free of the native XLA extension.

pub mod artifacts;

#[cfg(feature = "pjrt")]
pub mod client;
#[cfg(feature = "pjrt")]
pub mod encoder;

pub use artifacts::{ArtifactKind, ArtifactSpec, Manifest};

#[cfg(feature = "pjrt")]
pub use client::PjrtRunner;
#[cfg(feature = "pjrt")]
pub use encoder::PjrtEncoder;
