//! [`JobManager`]: bounded worker pool, job registry, progress events,
//! cancellation, metrics, and durable persistence through the store's
//! jobs section.
//!
//! Lifecycle: `submit` registers the job (persisting it as `Queued`),
//! a worker picks it up and runs it in cancellable chunks, and the
//! terminal transition (`Completed`/`Cancelled`/`Failed`) persists the
//! final state + result. A process killed mid-job therefore leaves a
//! `Queued`/`Running` job on disk, which the next open re-enqueues
//! from scratch (at-least-once; kinds are pure functions of the
//! immutable index). Graceful shutdown ([`Drop`]) deliberately does
//! *not* mark running jobs cancelled — they stay non-terminal on disk
//! so a restart resumes them.

use std::collections::{BTreeMap, VecDeque};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{ensure, Result};

use crate::coordinator::Engine;
use crate::obs::log::JsonLogger;
use crate::obs::prometheus::PromText;
use crate::obs::Stage;

use super::kinds::{self, JobHooks, RunOutcome};
use super::{
    JobEvent, JobKind, JobResult, JobSnapshot, JobSpec, JobStatus, PersistedJob,
    MAX_RETAINED_EVENTS, N_JOB_KINDS,
};

/// Lock a mutex, recovering from poisoning: job state is a snapshot
/// sink, always valid to read/write even if a holder panicked.
fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Log-spaced job-duration buckets in microseconds (upper bounds).
/// Jobs run orders of magnitude longer than requests, so these extend
/// from 1 ms to 10 min where the request buckets stop at 50 ms.
const JOB_BUCKETS_US: [u64; 10] = [
    1_000,
    10_000,
    50_000,
    250_000,
    1_000_000,
    5_000_000,
    30_000_000,
    120_000_000,
    600_000_000,
    u64::MAX,
];

/// Per-kind job counters and duration histograms (lock-free).
#[derive(Debug, Default)]
struct JobMetrics {
    submitted: [AtomicU64; N_JOB_KINDS],
    completed: [AtomicU64; N_JOB_KINDS],
    cancelled: [AtomicU64; N_JOB_KINDS],
    failed: [AtomicU64; N_JOB_KINDS],
    duration_buckets: [[AtomicU64; JOB_BUCKETS_US.len()]; N_JOB_KINDS],
    duration_sum_us: [AtomicU64; N_JOB_KINDS],
}

impl JobMetrics {
    fn record_duration(&self, kind: JobKind, us: u64) {
        let k = kind.index();
        self.duration_sum_us[k].fetch_add(us, Ordering::Relaxed);
        for (i, &ub) in JOB_BUCKETS_US.iter().enumerate() {
            if us <= ub {
                self.duration_buckets[k][i].fetch_add(1, Ordering::Relaxed);
                break;
            }
        }
    }
}

/// Mutable per-job state behind the job's mutex.
struct JobState {
    status: JobStatus,
    done: u64,
    total: u64,
    eta_us: Option<u64>,
    last_seq: u64,
    events: VecDeque<JobEvent>,
    result: Option<JobResult>,
}

/// One registered job: immutable identity + spec, a cancel flag the
/// worker polls between chunks, and the mutable state.
struct JobShared {
    id: u64,
    spec: JobSpec,
    cancel: AtomicBool,
    state: Mutex<JobState>,
}

impl JobShared {
    fn new(id: u64, spec: JobSpec, status: JobStatus, done: u64, total: u64, result: Option<JobResult>) -> Arc<JobShared> {
        Arc::new(JobShared {
            id,
            spec,
            cancel: AtomicBool::new(false),
            state: Mutex::new(JobState {
                status,
                done,
                total,
                eta_us: None,
                last_seq: 0,
                events: VecDeque::new(),
                result,
            }),
        })
    }

    fn snapshot(&self) -> JobSnapshot {
        let st = lock_unpoisoned(&self.state);
        JobSnapshot {
            id: self.id,
            kind: self.spec.kind(),
            status: st.status.clone(),
            done: st.done,
            total: st.total,
            eta_us: st.eta_us,
            latest_seq: st.last_seq,
        }
    }
}

/// Append an event, dropping the oldest past the retention cap.
fn push_event(
    st: &mut JobState,
    stage: Stage,
    done: u64,
    total: u64,
    eta_us: Option<u64>,
    message: String,
) {
    st.last_seq += 1;
    st.events.push_back(JobEvent { seq: st.last_seq, stage, done, total, eta_us, message });
    while st.events.len() > MAX_RETAINED_EVENTS {
        st.events.pop_front();
    }
}

/// The stage of the newest event, for terminal-transition events.
fn last_stage(st: &JobState) -> Stage {
    st.events.back().map(|e| e.stage).unwrap_or(Stage::LutCollapse)
}

/// Manager configuration.
#[derive(Debug, Clone, Copy)]
pub struct JobConfig {
    /// Worker threads executing jobs (≥ 1).
    pub n_workers: usize,
    /// Items per cancellation check / progress event. Cancels land
    /// within one chunk; smaller chunks mean faster cancels and more
    /// events.
    pub chunk: usize,
}

impl Default for JobConfig {
    fn default() -> Self {
        JobConfig { n_workers: 1, chunk: 16 }
    }
}

/// Shared manager internals (workers hold an `Arc`).
struct Inner {
    engine: Arc<Engine>,
    logger: Arc<JsonLogger>,
    /// Index path jobs persist into (`None` = in-memory only).
    persist: Option<PathBuf>,
    /// Serializes whole-file persistence (atomic tmp+rename saves
    /// would otherwise race on the tmp path).
    persist_gate: Mutex<()>,
    jobs: Mutex<BTreeMap<u64, Arc<JobShared>>>,
    queue: Mutex<VecDeque<u64>>,
    queue_cv: Condvar,
    next_id: AtomicU64,
    stop: AtomicBool,
    metrics: JobMetrics,
    chunk: usize,
}

/// The durable job plane: registry + bounded worker pool. See the
/// module docs ([`crate::jobs`]) for the lifecycle.
pub struct JobManager {
    inner: Arc<Inner>,
    workers: Vec<JoinHandle<()>>,
}

impl JobManager {
    /// Start a manager over `engine`. Jobs recovered from the store
    /// (`engine.recovered_jobs`) are re-registered: terminal jobs
    /// verbatim (results remain fetchable), non-terminal jobs
    /// re-enqueued from scratch. When `persist` is set, every submit
    /// and terminal transition rewrites the index file's jobs section.
    pub fn start(
        engine: Arc<Engine>,
        logger: Arc<JsonLogger>,
        persist: Option<PathBuf>,
        cfg: JobConfig,
    ) -> Arc<JobManager> {
        let inner = Arc::new(Inner {
            logger,
            persist,
            persist_gate: Mutex::new(()),
            jobs: Mutex::new(BTreeMap::new()),
            queue: Mutex::new(VecDeque::new()),
            queue_cv: Condvar::new(),
            next_id: AtomicU64::new(1),
            stop: AtomicBool::new(false),
            metrics: JobMetrics::default(),
            chunk: cfg.chunk.max(1),
            engine,
        });
        let mut max_id = 0u64;
        {
            let mut jobs = lock_unpoisoned(&inner.jobs);
            let mut queue = lock_unpoisoned(&inner.queue);
            for pj in &inner.engine.recovered_jobs {
                max_id = max_id.max(pj.id);
                let requeue = !pj.status.is_terminal();
                let (status, done) = if requeue {
                    (JobStatus::Queued, 0)
                } else {
                    (pj.status.clone(), pj.done)
                };
                let shared = JobShared::new(
                    pj.id,
                    pj.spec.clone(),
                    status,
                    done,
                    pj.total,
                    pj.result.clone(),
                );
                jobs.insert(pj.id, shared);
                if requeue {
                    queue.push_back(pj.id);
                    inner.logger.event(
                        "job_recovered",
                        &[
                            ("id", pj.id.into()),
                            ("kind", pj.spec.kind().name().into()),
                        ],
                    );
                }
            }
        }
        inner.next_id.store(max_id + 1, Ordering::Relaxed);
        let workers = (0..cfg.n_workers.max(1))
            .map(|_| {
                let inner = Arc::clone(&inner);
                std::thread::spawn(move || worker_loop(&inner))
            })
            .collect();
        inner.queue_cv.notify_all();
        Arc::new(JobManager { inner, workers })
    }

    /// Validate and enqueue a job; returns its id. The job is
    /// persisted as `Queued` before this returns, so a crash between
    /// submit and completion is recoverable.
    pub fn submit(&self, spec: JobSpec) -> Result<u64> {
        self.validate(&spec)?;
        let id = self.inner.next_id.fetch_add(1, Ordering::Relaxed);
        let shared = JobShared::new(id, spec.clone(), JobStatus::Queued, 0, 0, None);
        lock_unpoisoned(&self.inner.jobs).insert(id, shared);
        lock_unpoisoned(&self.inner.queue).push_back(id);
        self.inner.queue_cv.notify_one();
        let kind = spec.kind();
        self.inner.metrics.submitted[kind.index()].fetch_add(1, Ordering::Relaxed);
        self.inner.logger.event(
            "job_create",
            &[("id", id.into()), ("kind", kind.name().into())],
        );
        persist_all(&self.inner);
        Ok(id)
    }

    /// Reject specs that can never run on this engine, at submit time.
    fn validate(&self, spec: &JobSpec) -> Result<()> {
        let n = self.inner.engine.n_items;
        match spec {
            JobSpec::AllPairsTopK { k, nprobe, rerank, .. } => {
                ensure!(*k >= 1, "all_pairs_topk: k must be >= 1");
                if nprobe.is_some() {
                    ensure!(
                        self.inner.engine.ivf.is_some(),
                        "all_pairs_topk: nprobe needs an IVF index (rebuild with --nlist > 0)"
                    );
                }
                if let Some(r) = rerank {
                    ensure!(*r >= 1, "all_pairs_topk: rerank depth must be >= 1");
                }
            }
            JobSpec::ClusterSweep { k_clusters, max_iters, .. } => {
                ensure!(
                    *k_clusters >= 1 && *k_clusters <= n,
                    "cluster_sweep: k_clusters must be in 1..={n} (got {k_clusters})"
                );
                ensure!(*max_iters >= 1, "cluster_sweep: max_iters must be >= 1");
            }
            JobSpec::AutotuneNprobe { k, target_recall, sample } => {
                ensure!(*k >= 1, "autotune_nprobe: k must be >= 1");
                ensure!(
                    target_recall.is_finite()
                        && *target_recall > 0.0
                        && *target_recall <= 1.0,
                    "autotune_nprobe: target_recall must be in (0, 1] (got {target_recall})"
                );
                ensure!(*sample >= 1, "autotune_nprobe: sample must be >= 1");
                ensure!(
                    self.inner.engine.ivf.is_some(),
                    "autotune_nprobe needs an IVF index (rebuild with --nlist > 0)"
                );
            }
        }
        Ok(())
    }

    /// Point-in-time view of a job (`None` = unknown id).
    pub fn status(&self, id: u64) -> Option<JobSnapshot> {
        lock_unpoisoned(&self.inner.jobs).get(&id).map(|s| s.snapshot())
    }

    /// Events with `seq > cursor`, oldest first, at most `max`, plus
    /// the newest retained sequence number (`None` = unknown id).
    /// Retention is bounded (newest [`MAX_RETAINED_EVENTS`]); a stale
    /// cursor simply starts at the oldest retained event.
    pub fn events(&self, id: u64, cursor: u64, max: usize) -> Option<(Vec<JobEvent>, u64)> {
        let shared = lock_unpoisoned(&self.inner.jobs).get(&id).cloned()?;
        let st = lock_unpoisoned(&shared.state);
        let out = st
            .events
            .iter()
            .filter(|e| e.seq > cursor)
            .take(max)
            .cloned()
            .collect();
        Some((out, st.last_seq))
    }

    /// Request cancellation. A queued job cancels immediately; a
    /// running job stops at the next chunk boundary (its partial
    /// progress count stays consistent — exactly the chunks that
    /// finished). Terminal jobs are unaffected. Returns the post-call
    /// snapshot (`None` = unknown id).
    pub fn cancel(&self, id: u64) -> Option<JobSnapshot> {
        let shared = lock_unpoisoned(&self.inner.jobs).get(&id).cloned()?;
        shared.cancel.store(true, Ordering::Relaxed);
        let kind = shared.spec.kind();
        let mut terminal_now = false;
        {
            let mut st = lock_unpoisoned(&shared.state);
            match st.status {
                JobStatus::Queued => {
                    st.status = JobStatus::Cancelled;
                    let (stage, done, total) = (last_stage(&st), st.done, st.total);
                    push_event(&mut st, stage, done, total, None, "cancelled while queued".into());
                    terminal_now = true;
                }
                JobStatus::Running => {
                    self.inner.logger.event(
                        "job_cancel",
                        &[("id", id.into()), ("kind", kind.name().into())],
                    );
                }
                _ => {}
            }
        }
        if terminal_now {
            self.inner.metrics.cancelled[kind.index()].fetch_add(1, Ordering::Relaxed);
            self.inner.logger.event(
                "job_cancel",
                &[("id", id.into()), ("kind", kind.name().into())],
            );
            self.inner.logger.event(
                "job_done",
                &[
                    ("id", id.into()),
                    ("kind", kind.name().into()),
                    ("status", "cancelled".into()),
                    ("duration_us", 0u64.into()),
                ],
            );
            persist_all(&self.inner);
        }
        Some(shared.snapshot())
    }

    /// The result payload of a completed job. `None` = unknown id;
    /// `Some(None)` = known but not (yet) completed.
    pub fn result(&self, id: u64) -> Option<Option<JobResult>> {
        let shared = lock_unpoisoned(&self.inner.jobs).get(&id).cloned()?;
        let st = lock_unpoisoned(&shared.state);
        Some(st.result.clone())
    }

    /// `(running, queued)` job counts (the Prometheus gauges).
    pub fn counts(&self) -> (u64, u64) {
        let jobs = lock_unpoisoned(&self.inner.jobs);
        let mut running = 0u64;
        let mut queued = 0u64;
        for s in jobs.values() {
            match lock_unpoisoned(&s.state).status {
                JobStatus::Running => running += 1,
                JobStatus::Queued => queued += 1,
                _ => {}
            }
        }
        (running, queued)
    }

    /// Render the `pqdtw_jobs_*` families into an exposition builder.
    pub fn render_prometheus(&self, p: &mut PromText) {
        let (running, queued) = self.counts();
        p.gauge("pqdtw_jobs_running", running as f64);
        p.gauge("pqdtw_jobs_queued", queued as f64);
        let m = &self.inner.metrics;
        for (family, arr) in [
            ("pqdtw_jobs_submitted_total", &m.submitted),
            ("pqdtw_jobs_completed_total", &m.completed),
            ("pqdtw_jobs_cancelled_total", &m.cancelled),
            ("pqdtw_jobs_failed_total", &m.failed),
        ] {
            p.family(family, "counter");
            for kind in JobKind::ALL {
                p.sample(
                    family,
                    &[("kind", kind.name())],
                    arr[kind.index()].load(Ordering::Relaxed) as f64,
                );
            }
        }
        p.family("pqdtw_jobs_duration_microseconds", "histogram");
        for kind in JobKind::ALL {
            let hist: Vec<(u64, u64)> = JOB_BUCKETS_US
                .iter()
                .zip(m.duration_buckets[kind.index()].iter())
                .map(|(&ub, c)| (ub, c.load(Ordering::Relaxed)))
                .collect();
            let sum = m.duration_sum_us[kind.index()].load(Ordering::Relaxed);
            p.histogram_series(
                "pqdtw_jobs_duration_microseconds",
                &[("kind", kind.name())],
                &hist,
                sum as f64,
            );
        }
    }

    /// Snapshots of every registered job, ascending by id.
    pub fn list(&self) -> Vec<JobSnapshot> {
        lock_unpoisoned(&self.inner.jobs).values().map(|s| s.snapshot()).collect()
    }
}

impl Drop for JobManager {
    /// Graceful shutdown: stop the pool and join. Running jobs are
    /// abandoned *without* a terminal transition so their on-disk
    /// state stays `Queued`/`Running` and the next open re-enqueues
    /// them (crash and graceful exit recover identically).
    fn drop(&mut self) {
        self.inner.stop.store(true, Ordering::Relaxed);
        self.inner.queue_cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Per-run progress/cancellation context handed to the kind executors.
struct Ctx<'a> {
    shared: &'a JobShared,
    inner: &'a Inner,
    started: Instant,
}

impl JobHooks for Ctx<'_> {
    fn cancelled(&self) -> bool {
        self.shared.cancel.load(Ordering::Relaxed)
            || self.inner.stop.load(Ordering::Relaxed)
    }

    fn progress(&self, stage: Stage, done: u64, total: u64, message: String) {
        // ETA from observed throughput: elapsed * remaining / done.
        let eta_us = if done > 0 && done < total {
            let elapsed = self.started.elapsed().as_micros();
            u64::try_from(
                elapsed.saturating_mul(u128::from(total - done)) / u128::from(done),
            )
            .ok()
        } else {
            None
        };
        {
            let mut st = lock_unpoisoned(&self.shared.state);
            st.done = done;
            st.total = total;
            st.eta_us = eta_us;
            push_event(&mut st, stage, done, total, eta_us, message);
        }
        self.inner.logger.event(
            "job_progress",
            &[
                ("id", self.shared.id.into()),
                ("kind", self.shared.spec.kind().name().into()),
                ("stage", stage.name().into()),
                ("done", done.into()),
                ("total", total.into()),
            ],
        );
    }
}

/// Collect every job's persistable state and rewrite the index file's
/// jobs section (atomic tmp+rename; serialized by the persist gate).
fn persist_all(inner: &Inner) {
    let Some(path) = &inner.persist else { return };
    let _gate = lock_unpoisoned(&inner.persist_gate);
    let jobs: Vec<PersistedJob> = {
        let reg = lock_unpoisoned(&inner.jobs);
        reg.values()
            .map(|s| {
                let st = lock_unpoisoned(&s.state);
                PersistedJob {
                    id: s.id,
                    spec: s.spec.clone(),
                    status: st.status.clone(),
                    done: st.done,
                    total: st.total,
                    result: st.result.clone(),
                }
            })
            .collect()
    };
    let e = &inner.engine;
    if let Err(err) = crate::store::save_index_full(
        path,
        &e.pq,
        &e.encoded,
        &e.raw,
        e.ivf.as_ref(),
        &jobs,
        e.shard.as_ref(),
    ) {
        inner.logger.event(
            "job_persist_error",
            &[
                ("path", path.display().to_string().into()),
                ("error", err.to_string().into()),
            ],
        );
    }
}

/// Worker: pull ids off the queue, execute, transition, persist.
fn worker_loop(inner: &Inner) {
    loop {
        let id = {
            let mut q = lock_unpoisoned(&inner.queue);
            loop {
                if inner.stop.load(Ordering::Relaxed) {
                    return;
                }
                if let Some(id) = q.pop_front() {
                    break id;
                }
                q = inner
                    .queue_cv
                    .wait(q)
                    .unwrap_or_else(PoisonError::into_inner);
            }
        };
        let Some(shared) = lock_unpoisoned(&inner.jobs).get(&id).cloned() else {
            continue;
        };
        {
            let mut st = lock_unpoisoned(&shared.state);
            if st.status != JobStatus::Queued {
                continue; // cancelled while queued
            }
            st.status = JobStatus::Running;
        }
        let kind = shared.spec.kind();
        let started = Instant::now();
        let ctx = Ctx { shared: &shared, inner, started };
        let outcome = kinds::run(&inner.engine, &shared.spec, inner.chunk, &ctx);
        let duration_us = u64::try_from(started.elapsed().as_micros()).unwrap_or(u64::MAX);
        let final_status = match outcome {
            Ok(RunOutcome::Completed(result)) => {
                let mut st = lock_unpoisoned(&shared.state);
                st.done = st.total;
                st.eta_us = None;
                st.status = JobStatus::Completed;
                st.result = Some(result);
                let (stage, done, total) = (last_stage(&st), st.done, st.total);
                push_event(&mut st, stage, done, total, None, "completed".into());
                inner.metrics.completed[kind.index()].fetch_add(1, Ordering::Relaxed);
                "completed"
            }
            Ok(RunOutcome::Cancelled) => {
                if inner.stop.load(Ordering::Relaxed)
                    && !shared.cancel.load(Ordering::Relaxed)
                {
                    // Shutdown, not a user cancel: no terminal
                    // transition, so the persisted state stays
                    // non-terminal and a restart re-enqueues the job.
                    continue;
                }
                let mut st = lock_unpoisoned(&shared.state);
                st.status = JobStatus::Cancelled;
                st.eta_us = None;
                let (stage, done, total) = (last_stage(&st), st.done, st.total);
                push_event(
                    &mut st,
                    stage,
                    done,
                    total,
                    None,
                    format!("cancelled at {done}/{total}"),
                );
                inner.metrics.cancelled[kind.index()].fetch_add(1, Ordering::Relaxed);
                "cancelled"
            }
            Err(e) => {
                let mut st = lock_unpoisoned(&shared.state);
                st.status = JobStatus::Failed(e.to_string());
                st.eta_us = None;
                let (stage, done, total) = (last_stage(&st), st.done, st.total);
                push_event(&mut st, stage, done, total, None, format!("failed: {e}"));
                inner.metrics.failed[kind.index()].fetch_add(1, Ordering::Relaxed);
                "failed"
            }
        };
        inner.metrics.record_duration(kind, duration_us);
        inner.logger.event(
            "job_done",
            &[
                ("id", id.into()),
                ("kind", kind.name().into()),
                ("status", final_status.into()),
                ("duration_us", duration_us.into()),
            ],
        );
        persist_all(inner);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Engine, Request, Response};
    use crate::data::ucr_like::ucr_like_by_name;
    use crate::nn::ivf::CoarseMetric;
    use crate::nn::knn::PqQueryMode;
    use crate::pq::quantizer::PqConfig;

    fn toy_engine() -> Arc<Engine> {
        let tt = ucr_like_by_name("SpikePosition", 43).expect("dataset");
        let cfg = PqConfig {
            n_subspaces: 4,
            codebook_size: 8,
            window_frac: 0.2,
            kmeans_iters: 2,
            dba_iters: 1,
            ..Default::default()
        };
        let mut engine = Engine::build(&tt.train, &cfg, 1).expect("engine");
        engine.enable_ivf(4, CoarseMetric::Euclidean, 5);
        Arc::new(engine)
    }

    fn disabled_logger() -> Arc<JsonLogger> {
        Arc::new(JsonLogger::disabled())
    }

    fn wait_terminal(mgr: &JobManager, id: u64) -> JobSnapshot {
        for _ in 0..3000 {
            let snap = mgr.status(id).expect("job exists");
            if snap.status.is_terminal() {
                return snap;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        panic!("job {id} never reached a terminal state");
    }

    #[test]
    fn all_pairs_rows_match_serial_topk_bit_for_bit() {
        let engine = toy_engine();
        let mgr = JobManager::start(
            Arc::clone(&engine),
            disabled_logger(),
            None,
            JobConfig { n_workers: 1, chunk: 4 },
        );
        let spec = JobSpec::AllPairsTopK {
            k: 3,
            mode: PqQueryMode::Asymmetric,
            nprobe: None,
            rerank: Some(6),
        };
        let id = mgr.submit(spec).expect("submit");
        let snap = wait_terminal(&mgr, id);
        assert_eq!(snap.status, JobStatus::Completed, "{snap:?}");
        assert_eq!(snap.done, snap.total);
        let result = mgr.result(id).expect("known id").expect("completed");
        let JobResult::AllPairs(rows) = &result else {
            panic!("wrong result kind: {result:?}")
        };
        assert_eq!(rows.len(), engine.n_items);
        for row in rows {
            let i = usize::try_from(row.query_index).expect("index fits");
            let want = engine.handle(&Request::TopKQuery {
                series: engine.raw.row(i).to_vec(),
                k: 3,
                mode: PqQueryMode::Asymmetric,
                nprobe: None,
                rerank: Some(6),
            });
            let Response::TopK(want_hits) = want else { panic!("serial: {want:?}") };
            assert_eq!(row.hits.len(), want_hits.len());
            for (got, want) in row.hits.iter().zip(want_hits.iter()) {
                assert_eq!(got.index, want.index);
                assert_eq!(got.distance.to_bits(), want.distance.to_bits());
                assert_eq!(got.label, want.label);
            }
            assert_eq!(row.explains.len(), row.hits.len());
        }
    }

    #[test]
    fn cluster_sweep_is_deterministic_and_partitions_the_database() {
        let engine = toy_engine();
        let mgr = JobManager::start(
            Arc::clone(&engine),
            disabled_logger(),
            None,
            JobConfig { n_workers: 2, chunk: 8 },
        );
        let spec = JobSpec::ClusterSweep { k_clusters: 3, max_iters: 5, seed: 11 };
        let a = mgr.submit(spec.clone()).expect("submit a");
        let b = mgr.submit(spec).expect("submit b");
        assert_eq!(wait_terminal(&mgr, a).status, JobStatus::Completed);
        assert_eq!(wait_terminal(&mgr, b).status, JobStatus::Completed);
        let ra = mgr.result(a).expect("a").expect("a done");
        let rb = mgr.result(b).expect("b").expect("b done");
        assert_eq!(ra, rb, "same spec must yield a bit-identical result");
        let JobResult::Cluster { medoids, assignment, cost } = ra else {
            panic!("wrong kind")
        };
        assert_eq!(medoids.len(), 3);
        assert_eq!(assignment.len(), engine.n_items);
        assert!(assignment.iter().all(|&c| c < 3));
        assert!(cost.is_finite() && cost >= 0.0);
    }

    #[test]
    fn autotune_requires_ivf_and_full_probe_reaches_full_recall() {
        let tt = ucr_like_by_name("SpikePosition", 43).expect("dataset");
        let cfg = PqConfig {
            n_subspaces: 4,
            codebook_size: 8,
            window_frac: 0.2,
            kmeans_iters: 2,
            dba_iters: 1,
            ..Default::default()
        };
        let no_ivf = Arc::new(Engine::build(&tt.train, &cfg, 1).expect("engine"));
        let mgr = JobManager::start(
            no_ivf,
            disabled_logger(),
            None,
            JobConfig::default(),
        );
        let err = mgr
            .submit(JobSpec::AutotuneNprobe { k: 3, target_recall: 0.9, sample: 4 })
            .expect_err("no IVF index must be rejected at submit");
        assert!(err.to_string().contains("IVF"), "{err}");

        let engine = toy_engine();
        let mgr = JobManager::start(
            Arc::clone(&engine),
            disabled_logger(),
            None,
            JobConfig { n_workers: 1, chunk: 2 },
        );
        let id = mgr
            .submit(JobSpec::AutotuneNprobe { k: 3, target_recall: 1.0, sample: 6 })
            .expect("submit");
        let snap = wait_terminal(&mgr, id);
        assert_eq!(snap.status, JobStatus::Completed, "{snap:?}");
        let JobResult::Autotune { recommended_nprobe, sweep } =
            mgr.result(id).expect("known").expect("done")
        else {
            panic!("wrong kind")
        };
        let nlist = engine.ivf.as_ref().expect("ivf").nlist();
        let full = sweep.last().expect("non-empty sweep");
        assert_eq!(full.nprobe, nlist);
        assert!(
            (full.recall - 1.0).abs() < 1e-12,
            "probing every cell must reproduce the exhaustive scan, got {}",
            full.recall
        );
        assert!(recommended_nprobe >= 1 && recommended_nprobe <= nlist);
        assert!(sweep.windows(2).all(|w| w[0].nprobe < w[1].nprobe));
    }

    #[test]
    fn cancel_while_queued_is_immediate_and_events_are_cursor_addressable() {
        let engine = toy_engine();
        let mgr = JobManager::start(
            Arc::clone(&engine),
            disabled_logger(),
            None,
            // One worker: the first job occupies it, the second waits.
            JobConfig { n_workers: 1, chunk: 4 },
        );
        let running = mgr
            .submit(JobSpec::AllPairsTopK {
                k: 3,
                mode: PqQueryMode::Asymmetric,
                nprobe: None,
                rerank: Some(8),
            })
            .expect("submit running");
        let queued = mgr
            .submit(JobSpec::ClusterSweep { k_clusters: 2, max_iters: 3, seed: 1 })
            .expect("submit queued");
        let snap = mgr.cancel(queued).expect("known id");
        assert_eq!(snap.status, JobStatus::Cancelled);
        assert_eq!(snap.done, 0);
        let done = wait_terminal(&mgr, running);
        assert_eq!(done.status, JobStatus::Completed);
        // Events: cursor-addressable, strictly increasing seq.
        let (events, latest) = mgr.events(running, 0, 10_000).expect("events");
        assert!(!events.is_empty());
        assert_eq!(events.last().expect("last").seq, latest);
        assert!(events.windows(2).all(|w| w[0].seq < w[1].seq));
        let (tail, _) = mgr.events(running, latest - 1, 10_000).expect("tail");
        assert_eq!(tail.len(), 1);
        let (empty, _) = mgr.events(running, latest, 10_000).expect("empty");
        assert!(empty.is_empty());
        assert!(mgr.status(9999).is_none());
    }

    #[test]
    fn prometheus_families_render_and_validate_even_with_no_jobs() {
        let engine = toy_engine();
        let mgr = JobManager::start(
            engine,
            disabled_logger(),
            None,
            JobConfig::default(),
        );
        let mut p = PromText::new();
        mgr.render_prometheus(&mut p);
        let text = p.finish();
        let n = crate::obs::prometheus::validate_exposition(&text)
            .unwrap_or_else(|e| panic!("invalid exposition: {e}\n{text}"));
        assert!(n > 0);
        for family in [
            "pqdtw_jobs_running",
            "pqdtw_jobs_queued",
            "pqdtw_jobs_submitted_total",
            "pqdtw_jobs_completed_total",
            "pqdtw_jobs_cancelled_total",
            "pqdtw_jobs_failed_total",
            "pqdtw_jobs_duration_microseconds",
        ] {
            assert!(text.contains(family), "missing {family} in:\n{text}");
        }
    }
}
