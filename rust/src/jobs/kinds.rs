//! Execution of the three job kinds, in cancellable chunks.
//!
//! Every kind is a pure function of the immutable [`Engine`] and the
//! [`JobSpec`], so a re-run after crash recovery is bit-identical to
//! the interrupted run. Kinds report progress through [`JobHooks`] and
//! poll cancellation between chunks — a cancel therefore lands within
//! one chunk boundary, and the partial-progress count the job reports
//! is exactly the work that completed.
//!
//! Job phases are mapped onto the query stage ladder
//! ([`crate::obs::Stage`]): `blocked_scan` for distance scans
//! (all-pairs rows, k-medoids assignment), `rerank` for refinement
//! (medoid updates), `coarse_probe` for the autotune probe sweep.

use anyhow::{anyhow, bail, ensure, Result};

use crate::coordinator::{Engine, Request, Response};
use crate::obs::Stage;

use super::{AllPairsRow, JobResult, JobSpec, SweepPoint};

/// Callbacks a running job uses to report progress and observe
/// cancellation. Implemented by the manager's per-job context.
pub(crate) trait JobHooks {
    /// Should the job stop at the next chunk boundary?
    fn cancelled(&self) -> bool;
    /// Record progress: `done` of `total` items, currently in `stage`.
    fn progress(&self, stage: Stage, done: u64, total: u64, message: String);
}

/// How a run ended (failures surface as `Err`).
pub(crate) enum RunOutcome {
    /// Finished; the payload is ready to persist.
    Completed(JobResult),
    /// A cancel (or shutdown) landed on a chunk boundary.
    Cancelled,
}

/// Execute `spec` against `engine`, checking cancellation every
/// `chunk` items.
pub(crate) fn run(
    engine: &Engine,
    spec: &JobSpec,
    chunk: usize,
    hooks: &dyn JobHooks,
) -> Result<RunOutcome> {
    let chunk = chunk.max(1);
    match spec {
        JobSpec::AllPairsTopK { k, mode, nprobe, rerank } => {
            run_all_pairs(engine, *k, *mode, *nprobe, *rerank, chunk, hooks)
        }
        JobSpec::ClusterSweep { k_clusters, max_iters, seed } => {
            run_cluster_sweep(engine, *k_clusters, *max_iters, *seed, chunk, hooks)
        }
        JobSpec::AutotuneNprobe { k, target_recall, sample } => {
            run_autotune(engine, *k, *target_recall, *sample, chunk, hooks)
        }
    }
}

/// Run one top-k request through the engine, with tracing (per-hit
/// provenance) when `explain` is set.
fn topk(
    engine: &Engine,
    query_index: usize,
    k: usize,
    mode: crate::nn::knn::PqQueryMode,
    nprobe: Option<usize>,
    rerank: Option<usize>,
    explain: bool,
) -> Result<(Vec<crate::coordinator::Hit>, Vec<crate::obs::HitExplain>)> {
    let req = Request::TopKQuery {
        series: engine.raw.row(query_index).to_vec(),
        k,
        mode,
        nprobe,
        rerank,
    };
    let (resp, trace) = engine.handle_traced(&req, explain);
    match resp {
        Response::TopK(hits) => {
            let explains = trace.map(|t| t.hits).unwrap_or_default();
            Ok((hits, explains))
        }
        Response::Error(e) => bail!("query {query_index}: {e}"),
        other => bail!("query {query_index}: unexpected engine response {other:?}"),
    }
}

/// `AllPairsTopK`: every series vs. the database, one traced top-k
/// request per series. Rows are bit-identical to serial `TopK`
/// requests with the same parameters (`handle_traced` is
/// bit-transparent; loopback-tested in `tests/integration_jobs.rs`).
fn run_all_pairs(
    engine: &Engine,
    k: usize,
    mode: crate::nn::knn::PqQueryMode,
    nprobe: Option<usize>,
    rerank: Option<usize>,
    chunk: usize,
    hooks: &dyn JobHooks,
) -> Result<RunOutcome> {
    ensure!(k >= 1, "all_pairs_topk: k must be >= 1");
    let n = engine.n_items;
    let total = n as u64;
    let stage = if rerank.is_some() { Stage::Rerank } else { Stage::BlockedScan };
    hooks.progress(stage, 0, total, format!("all-pairs top-{k} over {n} series"));
    let mut rows = Vec::with_capacity(n);
    let mut start = 0usize;
    while start < n {
        if hooks.cancelled() {
            return Ok(RunOutcome::Cancelled);
        }
        let end = (start + chunk).min(n);
        for i in start..end {
            let (hits, explains) = topk(engine, i, k, mode, nprobe, rerank, true)?;
            rows.push(AllPairsRow { query_index: i as u64, hits, explains });
        }
        hooks.progress(stage, end as u64, total, format!("scanned queries {start}..{end}"));
        start = end;
    }
    Ok(RunOutcome::Completed(JobResult::AllPairs(rows)))
}

/// SplitMix64 step: the deterministic seed scrambler used for medoid
/// initialisation.
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// `k` distinct indices in `0..n`, deterministically from `seed`.
fn seeded_distinct(seed: u64, k: usize, n: usize) -> Vec<usize> {
    let mut state = seed;
    let mut taken = vec![false; n];
    let mut out = Vec::with_capacity(k);
    while out.len() < k {
        let mut idx = usize::try_from(splitmix64(&mut state) % (n as u64)).unwrap_or(0);
        while taken[idx] {
            idx = (idx + 1) % n;
        }
        taken[idx] = true;
        out.push(idx);
    }
    out.sort_unstable();
    out
}

/// `ClusterSweep`: k-medoids (PAM-style alternating assignment/update)
/// over PQ distances. Deterministic: seeded initialisation, total
/// `(distance, index)` orders everywhere, fixed iteration order.
fn run_cluster_sweep(
    engine: &Engine,
    k_clusters: usize,
    max_iters: usize,
    seed: u64,
    chunk: usize,
    hooks: &dyn JobHooks,
) -> Result<RunOutcome> {
    let n = engine.n_items;
    ensure!(
        k_clusters >= 1 && k_clusters <= n,
        "cluster_sweep: k_clusters must be in 1..={n} (got {k_clusters})"
    );
    let max_iters = max_iters.max(1);
    let dist = |i: usize, j: usize| engine.pq.patched_distance(&engine.encoded, i, j);
    let total = (max_iters as u64) * (n as u64);
    hooks.progress(
        Stage::BlockedScan,
        0,
        total,
        format!("k-medoids: {k_clusters} clusters over {n} series, <= {max_iters} rounds"),
    );
    let mut medoids = seeded_distinct(seed, k_clusters, n);
    let mut assignment = vec![0usize; n];
    let mut rounds_done = 0u64;
    for round in 0..max_iters {
        // Assignment step: nearest medoid by the (distance, slot) total
        // order, chunked so cancel lands between chunks.
        let mut start = 0usize;
        while start < n {
            if hooks.cancelled() {
                return Ok(RunOutcome::Cancelled);
            }
            let end = (start + chunk).min(n);
            for (i, slot) in assignment.iter_mut().enumerate().take(end).skip(start) {
                let mut best = (f64::INFINITY, 0usize);
                for (c, &m) in medoids.iter().enumerate() {
                    let d = dist(i, m);
                    if d.total_cmp(&best.0).is_lt() {
                        best = (d, c);
                    }
                }
                *slot = best.1;
            }
            hooks.progress(
                Stage::BlockedScan,
                rounds_done * (n as u64) + end as u64,
                total,
                format!("round {}: assigned {end}/{n}", round + 1),
            );
            start = end;
        }
        // Update step: per cluster, the member minimizing the summed
        // intra-cluster distance (ties to the smallest index).
        let mut new_medoids = medoids.clone();
        for c in 0..k_clusters {
            if hooks.cancelled() {
                return Ok(RunOutcome::Cancelled);
            }
            let members: Vec<usize> =
                (0..n).filter(|&i| assignment[i] == c).collect();
            if members.is_empty() {
                continue; // keep the old medoid for an empty cluster
            }
            let mut best = (f64::INFINITY, medoids[c]);
            for &cand in &members {
                let sum: f64 = members.iter().map(|&x| dist(cand, x)).sum();
                if sum.total_cmp(&best.0).is_lt() {
                    best = (sum, cand);
                }
            }
            new_medoids[c] = best.1;
        }
        rounds_done += 1;
        hooks.progress(
            Stage::Rerank,
            rounds_done * (n as u64),
            total,
            format!("round {}: medoids updated", round + 1),
        );
        if new_medoids == medoids {
            break; // converged — assignment is already vs. these medoids
        }
        medoids = new_medoids;
    }
    // Final assignment + cost against the final medoids.
    let mut cost = 0.0f64;
    for (i, slot) in assignment.iter_mut().enumerate() {
        let mut best = (f64::INFINITY, 0usize);
        for (c, &m) in medoids.iter().enumerate() {
            let d = dist(i, m);
            if d.total_cmp(&best.0).is_lt() {
                best = (d, c);
            }
        }
        *slot = best.1;
        cost += best.0;
    }
    Ok(RunOutcome::Completed(JobResult::Cluster { medoids, assignment, cost }))
}

/// `AutotuneNprobe`: sweep a doubling `nprobe` ladder over sampled
/// database queries, measure recall@k against the exhaustive scan, and
/// recommend the smallest width reaching the target (the paper's
/// accuracy/efficiency trade-off study as a job).
fn run_autotune(
    engine: &Engine,
    k: usize,
    target_recall: f64,
    sample: usize,
    chunk: usize,
    hooks: &dyn JobHooks,
) -> Result<RunOutcome> {
    ensure!(k >= 1, "autotune_nprobe: k must be >= 1");
    ensure!(
        target_recall.is_finite() && target_recall > 0.0 && target_recall <= 1.0,
        "autotune_nprobe: target_recall must be in (0, 1] (got {target_recall})"
    );
    let nlist = engine
        .ivf
        .as_ref()
        .map(|ivf| ivf.nlist())
        .ok_or_else(|| {
            anyhow!("autotune_nprobe needs an IVF index (rebuild with --nlist > 0)")
        })?;
    let n = engine.n_items;
    let sample = sample.clamp(1, n);
    // Doubling ladder capped by the list count, which is always swept
    // last (nprobe = nlist is bit-identical to the exhaustive scan).
    let mut candidates = Vec::new();
    let mut c = 1usize;
    while c < nlist {
        candidates.push(c);
        c = c.saturating_mul(2);
    }
    candidates.push(nlist);
    let total = sample as u64;
    hooks.progress(
        Stage::CoarseProbe,
        0,
        total,
        format!(
            "autotune: {} nprobe widths x {sample} sampled queries (target recall {target_recall})",
            candidates.len()
        ),
    );
    // Evenly spread sample of database series as queries.
    let step = (n / sample).max(1);
    let mut overlap = vec![0u64; candidates.len()];
    let mut truth_hits = 0u64;
    let mode = crate::nn::knn::PqQueryMode::Asymmetric;
    let mut done = 0usize;
    while done < sample {
        if hooks.cancelled() {
            return Ok(RunOutcome::Cancelled);
        }
        let end = (done + chunk).min(sample);
        for q in done..end {
            let qi = (q * step).min(n - 1);
            let (truth, _) = topk(engine, qi, k, mode, None, None, false)?;
            truth_hits += truth.len() as u64;
            for (ci, &np) in candidates.iter().enumerate() {
                let (probed, _) = topk(engine, qi, k, mode, Some(np), None, false)?;
                overlap[ci] += probed
                    .iter()
                    .filter(|h| truth.iter().any(|t| t.index == h.index))
                    .count() as u64;
            }
        }
        hooks.progress(
            Stage::CoarseProbe,
            end as u64,
            total,
            format!("swept queries {done}..{end}"),
        );
        done = end;
    }
    let denom = truth_hits.max(1) as f64;
    let sweep: Vec<SweepPoint> = candidates
        .iter()
        .zip(overlap.iter())
        .map(|(&np, &ov)| SweepPoint { nprobe: np, recall: ov as f64 / denom })
        .collect();
    let recommended_nprobe = sweep
        .iter()
        .find(|p| p.recall >= target_recall)
        .map(|p| p.nprobe)
        .unwrap_or(nlist);
    Ok(RunOutcome::Completed(JobResult::Autotune { recommended_nprobe, sweep }))
}
