//! Durable async job plane: long-running scans with progress
//! streaming, cancellation, and per-hit provenance.
//!
//! The TCP plane is strictly request/response, so any scan bigger than
//! a socket timeout — all-pairs similarity, full-database clustering,
//! recall-target `nprobe` sweeps — needs a different shape: submit,
//! poll, cancel, fetch. [`JobManager`] owns a bounded worker pool and a
//! registry of job kinds, each executing in cancellable chunks that
//! feed the existing [`crate::obs::ScanStats`] sinks and emit
//! [`JobEvent`]s (stage ladder reusing [`crate::obs::Stage`], items
//! done/total, ETA from observed throughput, per-hit
//! [`crate::obs::HitExplain`] provenance carried into persisted
//! results).
//!
//! Jobs survive restart: state + result payloads persist through the
//! store layer's jobs section (`docs/index-format.md`, format
//! version 2). Terminal jobs are recovered verbatim; a job that was
//! queued or running when the process died is re-enqueued from scratch
//! on the next open (at-least-once execution — every kind is a pure
//! function of the immutable index, so a re-run is bit-identical).
//!
//! The wire surface is protocol v3 (`JobCreate`/`JobStatus`/
//! `JobEvents`/`JobCancel`/`JobResult` frames, `docs/wire-protocol.md`)
//! and the `job submit|status|events|cancel|result` CLI verbs;
//! operational visibility is the `pqdtw_jobs_*` Prometheus families
//! and the `job_create`/`job_progress`/`job_cancel`/`job_done`
//! structured log events (`serve --log-json`).

#![cfg_attr(not(test), warn(clippy::unwrap_used, clippy::expect_used))]

mod kinds;
mod manager;

pub use manager::{JobConfig, JobManager};

use crate::coordinator::Hit;
use crate::nn::knn::PqQueryMode;
use crate::obs::{HitExplain, Stage};

/// Number of distinct job kinds (metric array dimension).
pub const N_JOB_KINDS: usize = 3;

/// The registry of job kinds. Discriminants are stable wire/store
/// identifiers (`as_u8`/`from_u8`), names are stable Prometheus
/// `kind` labels and CLI spellings.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobKind {
    /// Every database series queried against the full database.
    AllPairsTopK,
    /// k-medoids clustering over PQ distances.
    ClusterSweep,
    /// Recall-target `nprobe` sweep emitting a recommendation.
    AutotuneNprobe,
}

impl JobKind {
    /// All kinds, index-aligned with the per-kind metric arrays.
    pub const ALL: [JobKind; N_JOB_KINDS] =
        [JobKind::AllPairsTopK, JobKind::ClusterSweep, JobKind::AutotuneNprobe];

    /// Stable snake_case name (Prometheus `kind` label, log events).
    pub fn name(self) -> &'static str {
        match self {
            JobKind::AllPairsTopK => "all_pairs_topk",
            JobKind::ClusterSweep => "cluster_sweep",
            JobKind::AutotuneNprobe => "autotune_nprobe",
        }
    }

    /// Stable wire/store discriminant.
    pub fn as_u8(self) -> u8 {
        match self {
            JobKind::AllPairsTopK => 1,
            JobKind::ClusterSweep => 2,
            JobKind::AutotuneNprobe => 3,
        }
    }

    /// Inverse of [`JobKind::as_u8`]; `None` for unknown discriminants
    /// (hostile wire/store input).
    pub fn from_u8(v: u8) -> Option<JobKind> {
        match v {
            1 => Some(JobKind::AllPairsTopK),
            2 => Some(JobKind::ClusterSweep),
            3 => Some(JobKind::AutotuneNprobe),
            _ => None,
        }
    }

    /// Index into per-kind metric arrays.
    pub fn index(self) -> usize {
        match self {
            JobKind::AllPairsTopK => 0,
            JobKind::ClusterSweep => 1,
            JobKind::AutotuneNprobe => 2,
        }
    }
}

/// Full specification of a job: the kind plus its parameters. What a
/// client submits, what the store persists, what a re-run replays.
#[derive(Debug, Clone, PartialEq)]
pub enum JobSpec {
    /// Query every database series against the full database and keep
    /// each query's top-k with per-hit provenance. The serving-mode
    /// dial is the same as a `TopK` request.
    AllPairsTopK {
        /// Neighbours kept per query (≥ 1; self-matches included).
        k: usize,
        /// PQ query mode.
        mode: PqQueryMode,
        /// IVF probe width (`None` = exhaustive scan).
        nprobe: Option<usize>,
        /// Exact-DTW re-rank depth (`None` = PQ order).
        rerank: Option<usize>,
    },
    /// k-medoids over PQ distances (`patched_distance`), the paper's
    /// full-database clustering workload as a background job.
    ClusterSweep {
        /// Number of clusters (1 ..= database size).
        k_clusters: usize,
        /// Maximum assignment/update rounds.
        max_iters: usize,
        /// Seed for the deterministic medoid initialisation.
        seed: u64,
    },
    /// Sweep `nprobe` over a sample of database series, measuring
    /// recall of the probed scan against the exhaustive one, and
    /// recommend the smallest `nprobe` reaching `target_recall`.
    AutotuneNprobe {
        /// Top-k depth recall is measured at (≥ 1).
        k: usize,
        /// Recall target in (0, 1].
        target_recall: f64,
        /// Number of database series sampled as queries.
        sample: usize,
    },
}

impl JobSpec {
    /// The kind this spec instantiates.
    pub fn kind(&self) -> JobKind {
        match self {
            JobSpec::AllPairsTopK { .. } => JobKind::AllPairsTopK,
            JobSpec::ClusterSweep { .. } => JobKind::ClusterSweep,
            JobSpec::AutotuneNprobe { .. } => JobKind::AutotuneNprobe,
        }
    }
}

/// Lifecycle state of a job. Discriminants are stable wire/store
/// identifiers (`tag`); `Completed`/`Cancelled`/`Failed` are terminal.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum JobStatus {
    /// Submitted, waiting for a worker.
    Queued,
    /// A worker is executing chunks.
    Running,
    /// Finished; the result is available.
    Completed,
    /// Cancel landed on a chunk boundary; partial progress is final.
    Cancelled,
    /// Execution failed with a descriptive message.
    Failed(String),
}

impl JobStatus {
    /// Stable wire/store discriminant.
    pub fn tag(&self) -> u8 {
        match self {
            JobStatus::Queued => 0,
            JobStatus::Running => 1,
            JobStatus::Completed => 2,
            JobStatus::Cancelled => 3,
            JobStatus::Failed(_) => 4,
        }
    }

    /// Stable display name (log events, CLI output).
    pub fn name(&self) -> &'static str {
        match self {
            JobStatus::Queued => "queued",
            JobStatus::Running => "running",
            JobStatus::Completed => "completed",
            JobStatus::Cancelled => "cancelled",
            JobStatus::Failed(_) => "failed",
        }
    }

    /// No further transitions happen from this state.
    pub fn is_terminal(&self) -> bool {
        matches!(
            self,
            JobStatus::Completed | JobStatus::Cancelled | JobStatus::Failed(_)
        )
    }
}

/// One progress event, cursor-addressable by `seq`. Retention is
/// bounded (the newest [`MAX_RETAINED_EVENTS`] per job); a poll whose
/// cursor has fallen off the window still sees monotonic progress —
/// the window always holds the newest events.
#[derive(Debug, Clone, PartialEq)]
pub struct JobEvent {
    /// Monotonic per-job sequence number, starting at 1.
    pub seq: u64,
    /// The ladder stage the job is executing (reuses the query ladder).
    pub stage: Stage,
    /// Work items finished so far.
    pub done: u64,
    /// Total work items (fixed at job start).
    pub total: u64,
    /// Estimated microseconds to completion, from observed throughput
    /// (`None` until the first chunk lands).
    pub eta_us: Option<u64>,
    /// Human-readable detail (chunk summary, round number, …).
    pub message: String,
}

/// Events retained per job (oldest dropped past this).
pub const MAX_RETAINED_EVENTS: usize = 256;

/// Point-in-time view of a job (the `JobStatus` wire frame).
#[derive(Debug, Clone, PartialEq)]
pub struct JobSnapshot {
    /// Job id (unique per manager lifetime, including recovered jobs).
    pub id: u64,
    /// The kind submitted.
    pub kind: JobKind,
    /// Lifecycle state.
    pub status: JobStatus,
    /// Work items finished so far.
    pub done: u64,
    /// Total work items.
    pub total: u64,
    /// Estimated microseconds to completion (running jobs only).
    pub eta_us: Option<u64>,
    /// Sequence number of the newest event (0 = none yet) — the
    /// cursor high-water mark for `JobEvents` polls.
    pub latest_seq: u64,
}

/// One query's row in an [`JobResult::AllPairs`] payload.
#[derive(Debug, Clone, PartialEq)]
pub struct AllPairsRow {
    /// Database index of the query series.
    pub query_index: u64,
    /// Its top-k, bit-identical to a serial `TopK` request with the
    /// same parameters (ascending `(distance, index)`).
    pub hits: Vec<Hit>,
    /// Per-hit provenance, parallel to `hits` (the traced query's
    /// [`HitExplain`] list).
    pub explains: Vec<HitExplain>,
}

/// One measured point of an [`JobResult::Autotune`] sweep.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepPoint {
    /// Probe width measured.
    pub nprobe: usize,
    /// Mean recall@k of the probed scan against the exhaustive one.
    pub recall: f64,
}

/// Result payload of a completed job, persisted with the job.
#[derive(Debug, Clone, PartialEq)]
pub enum JobResult {
    /// Per-query top-k rows with provenance.
    AllPairs(Vec<AllPairsRow>),
    /// k-medoids outcome over PQ distances.
    Cluster {
        /// Database indices of the final medoids, in slot order
        /// (`assignment[i]` indexes this vector).
        medoids: Vec<usize>,
        /// Per-item medoid assignment (`assignment[i]` indexes
        /// `medoids`).
        assignment: Vec<usize>,
        /// Sum of PQ distances of items to their medoids.
        cost: f64,
    },
    /// `nprobe` sweep outcome.
    Autotune {
        /// Smallest swept `nprobe` whose recall reached the target
        /// (the full list width when none did).
        recommended_nprobe: usize,
        /// The measured sweep, ascending by `nprobe`.
        sweep: Vec<SweepPoint>,
    },
}

impl JobResult {
    /// The kind that produces this payload (store/wire discriminant
    /// cross-check).
    pub fn kind(&self) -> JobKind {
        match self {
            JobResult::AllPairs(_) => JobKind::AllPairsTopK,
            JobResult::Cluster { .. } => JobKind::ClusterSweep,
            JobResult::Autotune { .. } => JobKind::AutotuneNprobe,
        }
    }
}

/// A job as the store persists it (`docs/index-format.md`, jobs
/// section): identity, spec, last observed state, and the result when
/// terminal. Events are deliberately not persisted — they are a
/// bounded in-memory stream.
#[derive(Debug, Clone, PartialEq)]
pub struct PersistedJob {
    /// Job id at persist time; recovered ids are kept stable.
    pub id: u64,
    /// The spec, replayable verbatim.
    pub spec: JobSpec,
    /// Status at persist time. Non-terminal statuses mean the process
    /// died mid-job; recovery re-enqueues the spec from scratch.
    pub status: JobStatus,
    /// Progress at persist time (informational for non-terminal jobs).
    pub done: u64,
    /// Total work items (0 until the job started).
    pub total: u64,
    /// Result payload, present iff `status` is `Completed`.
    pub result: Option<JobResult>,
}
