//! Minimal CLI argument parsing (the offline registry has no `clap`).
//!
//! Grammar: `pqdtw <command> [--flag value]... [--switch]...`

use std::collections::HashMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// The subcommand (first positional argument).
    pub command: String,
    /// `--key value` pairs; bare `--switch` maps to "true".
    pub flags: HashMap<String, String>,
}

impl Args {
    /// Parse from an iterator of argument strings (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Self {
        let mut it = args.into_iter().peekable();
        let command = it.next().unwrap_or_default();
        let mut flags = HashMap::new();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                let value = match it.peek() {
                    Some(v) if !v.starts_with("--") => it.next().unwrap(),
                    _ => "true".to_string(),
                };
                flags.insert(key.to_string(), value);
            }
        }
        Args { command, flags }
    }

    /// Parse the process arguments.
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    /// String flag with default.
    pub fn get(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Typed flag with default.
    pub fn get_parsed<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Optional typed flag: `None` when absent or unparsable (used for
    /// flags whose absence selects a different serving mode, e.g.
    /// `--nprobe` / `--rerank`).
    pub fn get_opt<T: std::str::FromStr>(&self, key: &str) -> Option<T> {
        self.flags.get(key).and_then(|v| v.parse().ok())
    }

    /// Boolean switch.
    pub fn has(&self, key: &str) -> bool {
        self.flags.get(key).map(|v| v == "true").unwrap_or(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn parses_command_and_flags() {
        let a = parse("serve --workers 4 --verbose --seed 42");
        assert_eq!(a.command, "serve");
        assert_eq!(a.get_parsed("workers", 0usize), 4);
        assert_eq!(a.get_parsed("seed", 0u64), 42);
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn defaults() {
        let a = parse("selftest");
        assert_eq!(a.get("dataset", "CBF"), "CBF");
        assert_eq!(a.get_parsed("n", 10usize), 10);
    }

    #[test]
    fn optional_flags() {
        let a = parse("topk --nprobe 4");
        assert_eq!(a.get_opt::<usize>("nprobe"), Some(4));
        assert_eq!(a.get_opt::<usize>("rerank"), None);
        assert_eq!(a.get_opt::<usize>("verbose"), None); // unparsable
    }

    #[test]
    fn empty_args() {
        let a = Args::parse(Vec::<String>::new());
        assert_eq!(a.command, "");
    }
}
