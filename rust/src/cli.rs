//! Minimal CLI argument parsing (the offline registry has no `clap`).
//!
//! Grammar: `pqdtw <command> [--flag value]... [--switch]...`

use std::collections::HashMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// The subcommand (first positional argument).
    pub command: String,
    /// `--key value` pairs; bare `--switch` maps to "true".
    pub flags: HashMap<String, String>,
    /// Tokens that were neither a `--flag` nor a flag's value — usually
    /// a single-dash typo like `-nprobe`. Rejected by [`Args::validate`]
    /// (previously they were silently dropped).
    pub stray: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Self {
        let mut it = args.into_iter().peekable();
        let command = it.next().unwrap_or_default();
        let mut flags = HashMap::new();
        let mut stray = Vec::new();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                let value = match it.peek() {
                    Some(v) if !v.starts_with("--") => it.next().unwrap(),
                    _ => "true".to_string(),
                };
                flags.insert(key.to_string(), value);
            } else {
                stray.push(a);
            }
        }
        Args { command, flags, stray }
    }

    /// Parse the process arguments.
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    /// String flag with default.
    pub fn get(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Typed flag with default.
    pub fn get_parsed<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Optional typed flag: `None` when absent or unparsable (used for
    /// flags whose absence selects a different serving mode, e.g.
    /// `--nprobe` / `--rerank`).
    pub fn get_opt<T: std::str::FromStr>(&self, key: &str) -> Option<T> {
        self.flags.get(key).and_then(|v| v.parse().ok())
    }

    /// Boolean switch.
    pub fn has(&self, key: &str) -> bool {
        self.flags.get(key).map(|v| v == "true").unwrap_or(false)
    }

    /// Required string flag: an error naming the flag when absent (for
    /// flags like `--connect` that have no sensible default).
    pub fn require(&self, key: &str) -> Result<String, String> {
        self.flags.get(key).cloned().ok_or_else(|| format!("missing required flag --{key}"))
    }

    /// Promote a two-word subcommand: `pqdtw job submit --k 5` parses
    /// as command `job` with a stray `submit` token; this folds the
    /// action into the command (`job submit`) so spec validation sees
    /// the full verb. Errors when no action token is present.
    pub fn promote_action(&mut self) -> Result<(), String> {
        if self.stray.is_empty() {
            return Err(format!(
                "'{}' needs an action (e.g. `{} <action> --flag value`)",
                self.command, self.command
            ));
        }
        let action = self.stray.remove(0);
        self.command = format!("{} {}", self.command, action);
        Ok(())
    }

    /// Validate the parsed command line against a spec table: an
    /// unknown subcommand, or any flag the matched subcommand does not
    /// accept, is an error listing the valid options. Without this, a
    /// typo like `--nporbe` was silently ignored and quietly degraded
    /// results to the defaults.
    pub fn validate(&self, specs: &[CommandSpec]) -> Result<(), String> {
        let spec = match specs.iter().find(|s| s.name == self.command) {
            Some(s) => s,
            None => {
                let names: Vec<&str> = specs.iter().map(|s| s.name).collect();
                return Err(format!(
                    "unknown command '{}' (valid: {})",
                    self.command,
                    names.join("|")
                ));
            }
        };
        if let Some(first) = self.stray.first() {
            return Err(format!(
                "unexpected argument '{first}' (flags are spelled --name; values follow their flag)"
            ));
        }
        let mut unknown: Vec<&str> =
            self.flags.keys().map(|k| k.as_str()).filter(|k| !spec.flags.contains(k)).collect();
        unknown.sort_unstable();
        if let Some(first) = unknown.first() {
            let mut valid: Vec<&str> = spec.flags.to_vec();
            valid.sort_unstable();
            let valid: Vec<String> = valid.iter().map(|f| format!("--{f}")).collect();
            return Err(format!(
                "unknown flag --{first} for '{}' (valid: {})",
                spec.name,
                valid.join(" ")
            ));
        }
        Ok(())
    }
}

/// One subcommand and the exact flag set it accepts (used by
/// [`Args::validate`]).
#[derive(Debug, Clone, Copy)]
pub struct CommandSpec {
    /// Subcommand name.
    pub name: &'static str,
    /// Accepted flag names, without the `--` prefix.
    pub flags: &'static [&'static str],
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn parses_command_and_flags() {
        let a = parse("serve --workers 4 --verbose --seed 42");
        assert_eq!(a.command, "serve");
        assert_eq!(a.get_parsed("workers", 0usize), 4);
        assert_eq!(a.get_parsed("seed", 0u64), 42);
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn defaults() {
        let a = parse("selftest");
        assert_eq!(a.get("dataset", "CBF"), "CBF");
        assert_eq!(a.get_parsed("n", 10usize), 10);
    }

    #[test]
    fn optional_flags() {
        let a = parse("topk --nprobe 4");
        assert_eq!(a.get_opt::<usize>("nprobe"), Some(4));
        assert_eq!(a.get_opt::<usize>("rerank"), None);
        assert_eq!(a.get_opt::<usize>("verbose"), None); // unparsable
    }

    #[test]
    fn empty_args() {
        let a = Args::parse(Vec::<String>::new());
        assert_eq!(a.command, "");
    }

    #[test]
    fn require_names_the_missing_flag() {
        let a = parse("stats --connect 127.0.0.1:9000");
        assert_eq!(a.require("connect").unwrap(), "127.0.0.1:9000");
        let err = parse("stats").require("connect").unwrap_err();
        assert!(err.contains("--connect"), "{err}");
    }

    const SPECS: &[CommandSpec] = &[
        CommandSpec { name: "topk", flags: &["nprobe", "topk", "dataset"] },
        CommandSpec { name: "info", flags: &["index"] },
    ];

    #[test]
    fn validate_accepts_known_flags() {
        assert!(parse("topk --nprobe 4 --topk 5").validate(SPECS).is_ok());
        assert!(parse("info").validate(SPECS).is_ok());
        assert!(parse("info --index x.pqx").validate(SPECS).is_ok());
    }

    #[test]
    fn validate_rejects_misspelled_flag_listing_valid_ones() {
        let err = parse("topk --nporbe 4").validate(SPECS).unwrap_err();
        assert!(err.contains("--nporbe"), "{err}");
        assert!(err.contains("--nprobe"), "{err}");
        assert!(err.contains("'topk'"), "{err}");
    }

    #[test]
    fn validate_rejects_unknown_command_listing_valid_ones() {
        let err = parse("frobnicate --x 1").validate(SPECS).unwrap_err();
        assert!(err.contains("frobnicate"), "{err}");
        assert!(err.contains("topk"), "{err}");
        assert!(err.contains("info"), "{err}");
    }

    #[test]
    fn promote_action_folds_the_first_stray_into_the_command() {
        let mut a = parse("job submit --connect 127.0.0.1:7447");
        a.promote_action().unwrap();
        assert_eq!(a.command, "job submit");
        assert!(a.stray.is_empty());
        // A second stray is still a stray (and still rejected later).
        let mut a = parse("job events tail --id 3");
        a.promote_action().unwrap();
        assert_eq!(a.command, "job events");
        assert_eq!(a.stray, vec!["tail".to_string()]);
        // No action at all is an error naming the parent command.
        let err = parse("job --id 3").promote_action().unwrap_err();
        assert!(err.contains("'job'"), "{err}");
    }

    #[test]
    fn validate_rejects_single_dash_and_positional_strays() {
        // `-nprobe` is not parsed as a flag; before stray tracking it
        // (and its value) vanished silently.
        let err = parse("topk -nprobe 4").validate(SPECS).unwrap_err();
        assert!(err.contains("-nprobe"), "{err}");
        let err = parse("topk extra").validate(SPECS).unwrap_err();
        assert!(err.contains("extra"), "{err}");
        // flag values are consumed by their flag, not treated as stray
        assert!(parse("topk --nprobe 4").validate(SPECS).is_ok());
    }
}
