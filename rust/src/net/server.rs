//! The TCP serving plane: an accept loop feeding per-connection reader
//! threads into the shared [`Service`], so concurrent clients get
//! cross-connection dynamic batching for free.
//!
//! Std-only by design (`std::net` + threads; no tokio — see
//! `docs/DESIGN.md` §3). Each connection runs a reader thread (frames
//! in, requests submitted to the service) and a writer thread (replies
//! out, in request order); a bounded channel between them caps the
//! pipelined in-flight requests per connection, giving natural
//! backpressure. Hostile input never kills the process: malformed
//! payloads get an error frame on a still-synchronized stream, torn or
//! over-limit headers get a best-effort error frame and a disconnect.
//!
//! Shutdown is a drain: a `Shutdown` frame (or [`NetServer::shutdown`])
//! stops the accept loop, half-closes every connection's read side so
//! in-flight requests still get their replies, and joins every thread.

use std::collections::HashMap;
use std::net::{IpAddr, Ipv4Addr, Ipv6Addr, Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::{MetricsSnapshot, Request, RequestClass, Response, Service};
use crate::obs::log::JsonLogger;
use crate::obs::QueryTrace;

use super::protocol::{self, NetRequest, NetResponse, WireClassStats, WireStageStats, WireStats};

/// Serving-plane limits.
#[derive(Debug, Clone, Copy)]
pub struct ServerConfig {
    /// Maximum concurrent client connections; excess connects receive
    /// an error frame and are closed.
    pub max_connections: usize,
    /// Per-frame payload ceiling for incoming requests.
    pub max_frame_bytes: usize,
    /// Maximum pipelined requests in flight per connection; the reader
    /// blocks (TCP backpressure) once the writer is this far behind.
    pub max_in_flight: usize,
    /// Write timeout per response frame, bounding how long a drained
    /// shutdown can be held up by a client that stops reading.
    pub write_timeout: Duration,
    /// Engine-bound requests whose submit-to-reply wall time reaches
    /// this threshold emit a `slow_query` event and bump
    /// `pqdtw_slow_queries_total` (`serve --slow-query-ms`). `None`
    /// disables detection.
    pub slow_query_us: Option<u64>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_connections: 64,
            max_frame_bytes: protocol::MAX_FRAME_BYTES,
            max_in_flight: 32,
            write_timeout: Duration::from_secs(30),
            slow_query_us: None,
        }
    }
}

/// Lock a mutex, recovering from poison. A panicking connection
/// thread must not wedge the rest of the serving plane: the state
/// behind each of these locks (connection registry, join handles, the
/// done flag) stays consistent even if a holder unwound mid-update,
/// because every critical section completes its mutation in one step.
fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// State shared by the accept loop and every connection thread.
struct Shared {
    service: Arc<Service>,
    cfg: ServerConfig,
    /// Structured event log for the serving plane (disabled unless the
    /// operator passed `--log-json`; never stderr prints — the
    /// `no-raw-stderr-in-serving` lint enforces this).
    logger: Arc<JsonLogger>,
    local_addr: SocketAddr,
    stop: AtomicBool,
    active: AtomicUsize,
    next_conn: AtomicU64,
    /// Stream clones per live connection, so shutdown can half-close
    /// their read sides and unblock the reader threads.
    conns: Mutex<HashMap<u64, TcpStream>>,
    conn_threads: Mutex<Vec<JoinHandle<()>>>,
    done: (Mutex<bool>, Condvar),
}

impl Shared {
    /// Begin the drain exactly once: stop accepting, wake the accept
    /// loop, half-close every connection's read side (their writers
    /// still flush in-flight replies), and release [`NetServer::wait`].
    fn trigger(&self) {
        if self.stop.swap(true, Ordering::SeqCst) {
            return;
        }
        // Wake the accept loop with a throwaway connection to ourselves.
        let mut wake = self.local_addr;
        if wake.ip().is_unspecified() {
            wake.set_ip(match wake.ip() {
                IpAddr::V4(_) => IpAddr::V4(Ipv4Addr::LOCALHOST),
                IpAddr::V6(_) => IpAddr::V6(Ipv6Addr::LOCALHOST),
            });
        }
        let _ = TcpStream::connect_timeout(&wake, Duration::from_secs(1));
        for stream in lock_unpoisoned(&self.conns).values() {
            let _ = stream.shutdown(Shutdown::Read);
        }
        let (lock, cv) = &self.done;
        *lock_unpoisoned(lock) = true;
        cv.notify_all();
    }
}

/// A running TCP server over a [`Service`]. Dropping it (or calling
/// [`NetServer::shutdown`]) drains connections and joins every thread.
pub struct NetServer {
    shared: Arc<Shared>,
    accept_thread: Option<JoinHandle<()>>,
}

impl NetServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and
    /// start accepting connections over the shared service.
    pub fn start(addr: &str, service: Arc<Service>, cfg: ServerConfig) -> Result<NetServer> {
        NetServer::start_logged(addr, service, cfg, Arc::new(JsonLogger::disabled()))
    }

    /// [`NetServer::start`] with a structured event logger for the
    /// serving plane (`serve --log-json` wires stderr JSON-lines here).
    pub fn start_logged(
        addr: &str,
        service: Arc<Service>,
        cfg: ServerConfig,
        logger: Arc<JsonLogger>,
    ) -> Result<NetServer> {
        let listener = TcpListener::bind(addr).with_context(|| format!("net: binding {addr}"))?;
        let local_addr = listener.local_addr().context("net: reading bound address")?;
        logger.event("server_start", &[("addr", local_addr.to_string().into())]);
        let shared = Arc::new(Shared {
            service,
            cfg,
            logger,
            local_addr,
            stop: AtomicBool::new(false),
            active: AtomicUsize::new(0),
            next_conn: AtomicU64::new(0),
            conns: Mutex::new(HashMap::new()),
            conn_threads: Mutex::new(Vec::new()),
            done: (Mutex::new(false), Condvar::new()),
        });
        let accept_shared = Arc::clone(&shared);
        let accept_thread = std::thread::spawn(move || accept_loop(listener, accept_shared));
        Ok(NetServer { shared, accept_thread: Some(accept_thread) })
    }

    /// The address the server actually bound (resolves `:0` ports).
    pub fn local_addr(&self) -> SocketAddr {
        self.shared.local_addr
    }

    /// Live client connections right now.
    pub fn active_connections(&self) -> usize {
        self.shared.active.load(Ordering::SeqCst)
    }

    /// Block until a client's `Shutdown` frame stops the server, then
    /// drain and join every thread.
    pub fn wait(mut self) {
        {
            let (lock, cv) = &self.shared.done;
            let mut done = lock_unpoisoned(lock);
            while !*done {
                done = cv.wait(done).unwrap_or_else(PoisonError::into_inner);
            }
        }
        self.finish();
    }

    /// Stop the server from this side: drain connections, join threads.
    pub fn shutdown(mut self) {
        self.shared.trigger();
        self.finish();
    }

    fn finish(&mut self) {
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
        let handles: Vec<JoinHandle<()>> =
            lock_unpoisoned(&self.shared.conn_threads).drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.shared.trigger();
        self.finish();
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>) {
    for stream in listener.incoming() {
        if shared.stop.load(Ordering::SeqCst) {
            break;
        }
        let stream = match stream {
            Ok(s) => s,
            Err(_) => {
                // Accept failures can be persistent (e.g. EMFILE when
                // the fd limit is hit); back off briefly instead of
                // busy-spinning the accept thread. `stop` is re-checked
                // at the top of the next pass.
                std::thread::sleep(Duration::from_millis(20));
                continue;
            }
        };
        let _ = stream.set_nodelay(true);
        let _ = stream.set_write_timeout(Some(shared.cfg.write_timeout));
        if shared.active.load(Ordering::SeqCst) >= shared.cfg.max_connections {
            let mut stream = stream;
            shared.logger.event(
                "conn_rejected",
                &[("capacity", (shared.cfg.max_connections as u64).into())],
            );
            let frame = protocol::encode_response(&NetResponse::Error(format!(
                "server at its {}-connection capacity",
                shared.cfg.max_connections
            )));
            let _ = protocol::write_frame(&mut stream, &frame);
            let _ = stream.shutdown(Shutdown::Both);
            continue;
        }
        shared.active.fetch_add(1, Ordering::SeqCst);
        let id = shared.next_conn.fetch_add(1, Ordering::SeqCst);
        if shared.logger.is_enabled() {
            let peer = stream
                .peer_addr()
                .map(|a| a.to_string())
                .unwrap_or_else(|_| "unknown".into());
            shared
                .logger
                .event("conn_open", &[("conn", id.into()), ("peer", peer.into())]);
        }
        {
            // Register under the conns lock so a concurrent `trigger`
            // either sees this connection (and half-closes it) or its
            // `stop` store is visible here (and we half-close it
            // ourselves) — never neither, which would leave the reader
            // thread blocked forever and hang the shutdown joins.
            let mut conns = lock_unpoisoned(&shared.conns);
            if let Ok(clone) = stream.try_clone() {
                conns.insert(id, clone);
            }
            if shared.stop.load(Ordering::SeqCst) {
                let _ = stream.shutdown(Shutdown::Read);
            }
        }
        let conn_shared = Arc::clone(&shared);
        let handle = std::thread::spawn(move || handle_connection(stream, id, conn_shared));
        let mut threads = lock_unpoisoned(&shared.conn_threads);
        // Compact handles of connections that already finished (joining
        // a finished thread is instant, but the Vec should not grow
        // with the connection churn of a long-lived server).
        threads.retain(|t| !t.is_finished());
        threads.push(handle);
    }
}

/// One queued reply on a connection: either already materialized at the
/// net layer (ping/stats/errors) or pending from a service worker.
/// Pending replies carry the wire request id (stamped over the trace,
/// if any, before the result frame goes out) plus the submit instant
/// and request class, so the writer can detect slow queries end to end.
enum Outgoing {
    Ready(NetResponse),
    Pending {
        reply: mpsc::Receiver<(Response, Option<QueryTrace>)>,
        request_id: u64,
        submitted: Instant,
        class: &'static str,
    },
}

fn handle_connection(stream: TcpStream, id: u64, shared: Arc<Shared>) {
    let saw_shutdown = serve_connection(&stream, &shared);
    lock_unpoisoned(&shared.conns).remove(&id);
    shared.logger.event("conn_close", &[("conn", id.into())]);
    shared.active.fetch_sub(1, Ordering::SeqCst);
    let _ = stream.shutdown(Shutdown::Both);
    if saw_shutdown {
        // Trigger *after* the writer flushed the ShutdownAck, and from
        // this thread (trigger never joins, so no self-join deadlock).
        shared.trigger();
    }
}

/// Reader half of a connection; returns whether a `Shutdown` frame was
/// served (the caller then triggers the server-wide drain).
fn serve_connection(stream: &TcpStream, shared: &Arc<Shared>) -> bool {
    let mut reader = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return false,
    };
    let writer_stream = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return false,
    };
    let (tx, rx) = mpsc::sync_channel::<Outgoing>(shared.cfg.max_in_flight.max(1));
    let writer_shared = Arc::clone(shared);
    let writer = std::thread::spawn(move || write_loop(writer_stream, rx, writer_shared));
    let mut saw_shutdown = false;
    loop {
        match protocol::read_frame(&mut reader, shared.cfg.max_frame_bytes) {
            Ok(None) => break, // client closed between frames
            Ok(Some((tag, payload))) => match protocol::decode_request(tag, &payload) {
                Ok(req) => {
                    saw_shutdown = matches!(req, NetRequest::Shutdown);
                    let out = dispatch(req, shared);
                    if tx.send(out).is_err() || saw_shutdown {
                        break;
                    }
                }
                Err(e) => {
                    // The payload was length-delimited and fully read,
                    // so the stream is still frame-synchronized: report
                    // and keep serving this connection.
                    shared
                        .logger
                        .event("bad_request", &[("error", format!("{e:#}").into())]);
                    let out = Outgoing::Ready(NetResponse::Error(format!("{e:#}")));
                    if tx.send(out).is_err() {
                        break;
                    }
                }
            },
            Err(e) => {
                // Torn header, bad magic/version, or over-limit length:
                // the stream can no longer be trusted to be on a frame
                // boundary. Best-effort error frame, then disconnect.
                shared
                    .logger
                    .event("frame_error", &[("error", format!("{e:#}").into())]);
                let _ = tx.send(Outgoing::Ready(NetResponse::Error(format!("{e:#}"))));
                drain_best_effort(&mut reader);
                break;
            }
        }
    }
    drop(tx);
    let _ = writer.join();
    saw_shutdown
}

/// Bounded best-effort drain after a framing error: consuming what the
/// peer already sent lets the close that follows end with FIN instead
/// of RST (an RST while an oversized payload sits unread could destroy
/// the error frame in the peer's receive buffer before it reads it).
/// Both the byte cap and the read timeout keep a hostile peer from
/// holding the connection open.
fn drain_best_effort(stream: &mut TcpStream) {
    use std::io::Read;
    let _ = stream.set_read_timeout(Some(Duration::from_millis(250)));
    let mut scratch = [0u8; 4096];
    let mut drained = 0usize;
    while drained < 256 * 1024 {
        match stream.read(&mut scratch) {
            Ok(0) => break,
            Ok(n) => drained += n,
            Err(_) => break,
        }
    }
}

/// Map one decoded request to its (possibly pending) reply, recording
/// net-plane classes into the shared metrics sink. Engine-bound
/// requests are metered by the service workers themselves.
fn dispatch(req: NetRequest, shared: &Shared) -> Outgoing {
    if shared.logger.is_enabled() {
        let kind = match &req {
            NetRequest::Ping => "ping",
            NetRequest::Stats => "stats",
            NetRequest::MetricsText => "metrics_text",
            NetRequest::Shutdown => "shutdown",
            NetRequest::Nn { .. } => "nn",
            NetRequest::TopK { .. } => "topk",
            NetRequest::JobCreate { .. } => "job_create",
            NetRequest::JobStatus { .. } => "job_status",
            NetRequest::JobEvents { .. } => "job_events",
            NetRequest::JobCancel { .. } => "job_cancel",
            NetRequest::JobResult { .. } => "job_result",
        };
        shared.logger.event("request", &[("kind", kind.into())]);
    }
    match req {
        NetRequest::Ping => {
            shared.service.record_external(RequestClass::Ping, 0, false);
            Outgoing::Ready(NetResponse::Pong)
        }
        NetRequest::Stats => {
            let t0 = Instant::now();
            let stats = wire_stats_full(&shared.service);
            shared.service.record_external(
                RequestClass::Stats,
                t0.elapsed().as_micros() as u64,
                false,
            );
            Outgoing::Ready(NetResponse::Stats(stats))
        }
        NetRequest::MetricsText => {
            let t0 = Instant::now();
            let text = shared.service.prometheus_text();
            shared.service.record_external(
                RequestClass::Stats,
                t0.elapsed().as_micros() as u64,
                false,
            );
            Outgoing::Ready(NetResponse::MetricsText(text))
        }
        NetRequest::Shutdown => Outgoing::Ready(NetResponse::ShutdownAck),
        NetRequest::Nn { series, mode, nprobe, request_id, trace } => {
            submit(shared, Request::NnQuery { series, mode, nprobe }, request_id, trace)
        }
        NetRequest::TopK { series, k, mode, nprobe, rerank, request_id, trace } => submit(
            shared,
            Request::TopKQuery { series, k, mode, nprobe, rerank },
            request_id,
            trace,
        ),
        req @ (NetRequest::JobCreate { .. }
        | NetRequest::JobStatus { .. }
        | NetRequest::JobEvents { .. }
        | NetRequest::JobCancel { .. }
        | NetRequest::JobResult { .. }) => dispatch_job(req, shared),
    }
}

/// Job-plane control frames are answered inline (`Outgoing::Ready`):
/// every manager call is a registry lookup, never a scan, so nothing
/// here blocks the connection reader.
fn dispatch_job(req: NetRequest, shared: &Shared) -> Outgoing {
    let t0 = Instant::now();
    let resp = match shared.service.jobs() {
        None => NetResponse::Error("job plane not enabled on this server".into()),
        Some(mgr) => match req {
            NetRequest::JobCreate { spec } => match mgr.submit(spec) {
                Ok(id) => NetResponse::JobCreated { id },
                Err(e) => NetResponse::Error(format!("{e:#}")),
            },
            NetRequest::JobStatus { id } => match mgr.status(id) {
                Some(snap) => NetResponse::JobStatus(snap),
                None => NetResponse::Error(format!("unknown job id {id}")),
            },
            NetRequest::JobEvents { id, cursor, max } => match mgr.events(id, cursor, max) {
                Some((events, latest_seq)) => NetResponse::JobEvents { events, latest_seq },
                None => NetResponse::Error(format!("unknown job id {id}")),
            },
            // A cancel is acknowledged with the post-cancel status frame
            // so the client sees the terminal (or soon-terminal) state
            // without a second round trip.
            NetRequest::JobCancel { id } => match mgr.cancel(id) {
                Some(snap) => NetResponse::JobStatus(snap),
                None => NetResponse::Error(format!("unknown job id {id}")),
            },
            NetRequest::JobResult { id } => match mgr.result(id) {
                Some(Some(result)) => NetResponse::JobResult(result),
                Some(None) => NetResponse::Error(format!("job {id} has no result yet")),
                None => NetResponse::Error(format!("unknown job id {id}")),
            },
            // Unreachable: the caller only routes job frames here.
            other => NetResponse::Error(format!("net: not a job frame: {other:?}")),
        },
    };
    let is_err = matches!(resp, NetResponse::Error(_));
    shared.service.record_external(
        RequestClass::JobControl,
        t0.elapsed().as_micros() as u64,
        is_err,
    );
    Outgoing::Ready(resp)
}

fn submit(shared: &Shared, req: Request, request_id: u64, trace: bool) -> Outgoing {
    let class = req.class().name();
    match shared.service.submit_traced(req, trace) {
        Some(reply) => {
            Outgoing::Pending { reply, request_id, submitted: Instant::now(), class }
        }
        None => Outgoing::Ready(NetResponse::Error("service closed".into())),
    }
}

/// Writer half of a connection: replies go out strictly in request
/// order, draining whatever is still queued when the reader stops.
fn write_loop(mut stream: TcpStream, rx: mpsc::Receiver<Outgoing>, shared: Arc<Shared>) {
    while let Ok(out) = rx.recv() {
        let resp = match out {
            Outgoing::Ready(resp) => resp,
            Outgoing::Pending { reply, request_id, submitted, class } => match reply.recv() {
                Ok((resp, mut trace)) => {
                    // The engine doesn't know wire ids; stamp the
                    // client's id onto the trace it asked for.
                    if let Some(t) = &mut trace {
                        t.request_id = request_id;
                    }
                    observe_slow_query(&shared, request_id, class, submitted, trace.as_ref());
                    engine_to_net(resp, trace)
                }
                Err(_) => NetResponse::Error("worker dropped request".into()),
            },
        };
        let frame = protocol::encode_response(&resp);
        if protocol::write_frame(&mut stream, &frame).is_err() {
            break; // client gone; reader notices via the closed channel
        }
    }
}

/// Slow-query detection for engine-bound requests, measured submit to
/// reply (queueing + batching + engine time — what the client actually
/// waited, minus socket transfer). Crossing the `--slow-query-ms`
/// threshold bumps `pqdtw_slow_queries_total` and emits one
/// `slow_query` event; `spans` carries the per-stage wall-time summary
/// when the request was traced (empty otherwise), `degraded` is always
/// false on a single-node server (the field exists so the router's
/// events have the same shape).
fn observe_slow_query(
    shared: &Shared,
    request_id: u64,
    class: &'static str,
    submitted: Instant,
    trace: Option<&QueryTrace>,
) {
    let Some(threshold_us) = shared.cfg.slow_query_us else {
        return;
    };
    let wall_us = u64::try_from(submitted.elapsed().as_micros()).unwrap_or(u64::MAX);
    if wall_us < threshold_us {
        return;
    }
    shared.service.record_slow_query();
    shared.logger.event(
        "slow_query",
        &[
            ("request_id", request_id.into()),
            ("class", class.into()),
            ("wall_us", wall_us.into()),
            ("degraded", false.into()),
            ("spans", trace.map(QueryTrace::span_summary).unwrap_or_default().into()),
        ],
    );
}

fn engine_to_net(resp: Response, trace: Option<QueryTrace>) -> NetResponse {
    match resp {
        Response::Nn { index, distance, label } => NetResponse::Nn {
            index,
            distance,
            label,
            trace,
            degraded: false,
            missing_shards: Vec::new(),
        },
        Response::TopK(hits) => {
            NetResponse::TopK { hits, trace, degraded: false, missing_shards: Vec::new() }
        }
        Response::Error(msg) => NetResponse::Error(msg),
        // The wire vocabulary deliberately has no encode/pair-dist
        // verbs, so the engine cannot produce these for a net request.
        Response::Codes(_) | Response::Dist(_) => {
            NetResponse::Error("unexpected engine response".into())
        }
    }
}

/// Project a [`MetricsSnapshot`] onto the wire stats frame. The
/// service-level fields (uptime, version, index header, scan counters)
/// are zeroed here; [`wire_stats_full`] stamps them from a live
/// service.
pub fn wire_stats(m: &MetricsSnapshot) -> WireStats {
    WireStats {
        requests: m.requests,
        errors: m.errors,
        batches: m.batches,
        mean_batch_size: m.mean_batch_size,
        mean_latency_us: m.mean_latency_us,
        p50_us: m.percentile_us(0.5),
        p99_us: m.percentile_us(0.99),
        // Raw per-bucket counts ride along with every percentile so the
        // router can merge distributions exactly instead of
        // approximating fleet percentiles from per-shard scalars.
        latency_buckets: m.histogram.iter().map(|&(_, c)| c).collect(),
        per_class: m
            .per_class
            .iter()
            .enumerate()
            .map(|(i, c)| WireClassStats {
                class: i as u8,
                name: c.class.name().to_string(),
                requests: c.requests,
                mean_latency_us: c.mean_latency_us,
                p50_us: c.p50_us,
                p99_us: c.p99_us,
                buckets: c.histogram.iter().map(|&(_, n)| n).collect(),
            })
            .collect(),
        per_stage: m
            .per_stage
            .iter()
            .map(|s| WireStageStats {
                stage: s.stage.as_u8(),
                name: s.stage.name().to_string(),
                count: s.count,
                mean_us: s.mean_us,
                p50_us: s.p50_us,
                p99_us: s.p99_us,
                buckets: s.histogram.iter().map(|&(_, n)| n).collect(),
            })
            .collect(),
        scan: Default::default(),
        uptime_s: 0,
        version: String::new(),
        n_items: 0,
        n_subspaces: 0,
        codebook_size: 0,
        series_len: 0,
        window_frac: 0.0,
        coarse_metric: String::new(),
        nlist: None,
    }
}

/// [`wire_stats`] plus the live-service fields: engine scan counters,
/// index header summary, uptime, and crate version.
pub fn wire_stats_full(service: &Service) -> WireStats {
    let mut s = wire_stats(&service.metrics());
    let info = service.engine().info();
    s.scan = service.engine().scan_stats();
    s.uptime_s = service.uptime_s();
    s.version = env!("CARGO_PKG_VERSION").to_string();
    s.n_items = info.n_items as u64;
    s.n_subspaces = info.n_subspaces as u64;
    s.codebook_size = info.codebook_size as u64;
    s.series_len = info.series_len as u64;
    s.window_frac = info.window_frac;
    s.coarse_metric = info.coarse_metric;
    s.nlist = info.nlist;
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Metrics;

    #[test]
    fn wire_stats_projects_every_class() {
        let m = Metrics::new();
        m.record_request(RequestClass::TopKProbed, 120, false);
        m.record_request(RequestClass::Ping, 1, false);
        let s = wire_stats(&m.snapshot());
        assert_eq!(s.requests, 2);
        assert_eq!(s.per_class.len(), crate::coordinator::metrics::N_REQUEST_CLASSES);
        let probed = s.per_class.iter().find(|c| c.name == "topk_probed").unwrap();
        assert_eq!(probed.requests, 1);
        assert!(probed.p50_us >= 100);
        let ping = s.per_class.iter().find(|c| c.name == "ping").unwrap();
        assert_eq!(ping.requests, 1);
    }

    #[test]
    fn wire_stats_carry_raw_bucket_counts() {
        use crate::coordinator::BUCKETS_US;
        let m = Metrics::new();
        m.record_request(RequestClass::Nn, 120, false); // lands in the 250µs bucket
        m.record_request(RequestClass::Nn, 3, false); // lands in the 10µs bucket
        let s = wire_stats(&m.snapshot());
        assert_eq!(s.latency_buckets.len(), protocol::N_LATENCY_BUCKETS);
        assert_eq!(s.latency_buckets.iter().sum::<u64>(), 2);
        assert_eq!(s.latency_buckets[0], 1);
        let idx_250 = BUCKETS_US.iter().position(|&ub| ub == 250).unwrap();
        assert_eq!(s.latency_buckets[idx_250], 1);
        let nn = s.per_class.iter().find(|c| c.name == "nn").unwrap();
        assert_eq!(nn.buckets, s.latency_buckets);
        for c in &s.per_class {
            assert_eq!(c.buckets.len(), protocol::N_LATENCY_BUCKETS);
        }
        for st in &s.per_stage {
            assert_eq!(st.buckets.len(), protocol::N_LATENCY_BUCKETS);
        }
    }

    #[test]
    fn wire_stats_projects_every_stage() {
        use crate::obs::Stage;
        let m = Metrics::new();
        m.record_stage(Stage::BlockedScan, 40);
        m.record_stage(Stage::Rerank, 900);
        let s = wire_stats(&m.snapshot());
        assert_eq!(s.per_stage.len(), crate::obs::N_STAGES);
        let scan = s.per_stage.iter().find(|st| st.name == "blocked_scan").unwrap();
        assert_eq!(scan.count, 1);
        assert_eq!(scan.stage, Stage::BlockedScan.as_u8());
        assert!(scan.p50_us >= 40);
        let lut = s.per_stage.iter().find(|st| st.name == "lut_collapse").unwrap();
        assert_eq!(lut.count, 0);
    }
}
