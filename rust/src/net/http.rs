//! Native HTTP/1.1 scrape endpoint for the observability plane
//! (`serve --metrics-listen <addr>`), std-only like the rest of the
//! serving stack.
//!
//! Prometheus and load balancers speak plain HTTP, not the PQDTWNET
//! frame protocol, so the `MetricsText` wire verb alone leaves the
//! exposition unreachable from a stock scraper. This listener answers
//! exactly two routes — `GET /metrics` (text exposition) and
//! `GET /healthz` (JSON health body) — and nothing else.
//!
//! Hardening mirrors the frame server's discipline, scaled down to the
//! protocol's simplicity:
//!
//! - one request per connection, always `Connection: close` — no
//!   keep-alive state machine to get wrong;
//! - the request head is read under a byte cap and a read timeout, so
//!   a hostile peer can neither balloon memory nor pin a thread;
//! - anything that is not a well-formed `GET` of a known route gets a
//!   minimal error status (`400`/`404`/`405`) and a disconnect;
//! - connections past the cap receive `503` without a thread spawn.
//!
//! Route bodies come from caller-supplied closures, so the same
//! listener serves the single-node plane (service exposition) and the
//! router plane (router exposition + per-shard health) without this
//! module knowing either.

use std::io::{Read, Write};
use std::net::{IpAddr, Ipv4Addr, Ipv6Addr, Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::obs::log::JsonLogger;

/// A route body provider: called once per matching request, returns
/// the current body text.
pub type BodyFn = Arc<dyn Fn() -> String + Send + Sync>;

/// The two routes the endpoint serves.
#[derive(Clone)]
pub struct HttpEndpoints {
    /// `GET /metrics` — Prometheus text exposition.
    pub metrics: BodyFn,
    /// `GET /healthz` — JSON health body.
    pub healthz: BodyFn,
}

impl std::fmt::Debug for HttpEndpoints {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("HttpEndpoints").finish_non_exhaustive()
    }
}

/// Scrape-endpoint limits.
#[derive(Debug, Clone, Copy)]
pub struct HttpConfig {
    /// Maximum concurrent scrape connections; excess connects receive
    /// `503` and are closed without spawning a thread.
    pub max_connections: usize,
    /// Byte cap on the request head (request line + headers); larger
    /// heads get `400` and a disconnect.
    pub max_request_bytes: usize,
    /// How long a connection may dribble its request head.
    pub read_timeout: Duration,
    /// Write timeout per response.
    pub write_timeout: Duration,
}

impl Default for HttpConfig {
    fn default() -> Self {
        HttpConfig {
            max_connections: 16,
            max_request_bytes: 8 * 1024,
            read_timeout: Duration::from_secs(5),
            write_timeout: Duration::from_secs(5),
        }
    }
}

/// Lock a mutex, recovering from poison — a panicking scrape thread
/// must not wedge shutdown (same discipline as the frame server).
fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

struct Shared {
    endpoints: HttpEndpoints,
    cfg: HttpConfig,
    logger: Arc<JsonLogger>,
    local_addr: SocketAddr,
    stop: AtomicBool,
    active: AtomicUsize,
    conn_threads: Mutex<Vec<JoinHandle<()>>>,
}

/// A running scrape endpoint. Dropping it (or calling
/// [`HttpServer::shutdown`]) stops the accept loop and joins every
/// connection thread.
pub struct HttpServer {
    shared: Arc<Shared>,
    accept_thread: Option<JoinHandle<()>>,
}

impl HttpServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"`) and start answering scrapes.
    pub fn start(
        addr: &str,
        endpoints: HttpEndpoints,
        cfg: HttpConfig,
        logger: Arc<JsonLogger>,
    ) -> Result<HttpServer> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("http: binding {addr}"))?;
        let local_addr = listener.local_addr().context("http: reading bound address")?;
        logger.event("metrics_http_start", &[("addr", local_addr.to_string().into())]);
        let shared = Arc::new(Shared {
            endpoints,
            cfg,
            logger,
            local_addr,
            stop: AtomicBool::new(false),
            active: AtomicUsize::new(0),
            conn_threads: Mutex::new(Vec::new()),
        });
        let accept_shared = Arc::clone(&shared);
        let accept_thread = std::thread::spawn(move || accept_loop(listener, accept_shared));
        Ok(HttpServer { shared, accept_thread: Some(accept_thread) })
    }

    /// The address the endpoint actually bound (resolves `:0` ports).
    pub fn local_addr(&self) -> SocketAddr {
        self.shared.local_addr
    }

    /// Stop accepting, join the accept loop and every scrape thread.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        if !self.shared.stop.swap(true, Ordering::SeqCst) {
            // Wake the accept loop with a throwaway connection.
            let mut wake = self.shared.local_addr;
            if wake.ip().is_unspecified() {
                wake.set_ip(match wake.ip() {
                    IpAddr::V4(_) => IpAddr::V4(Ipv4Addr::LOCALHOST),
                    IpAddr::V6(_) => IpAddr::V6(Ipv6Addr::LOCALHOST),
                });
            }
            let _ = TcpStream::connect_timeout(&wake, Duration::from_secs(1));
        }
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
        let handles: Vec<JoinHandle<()>> =
            lock_unpoisoned(&self.shared.conn_threads).drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>) {
    for stream in listener.incoming() {
        if shared.stop.load(Ordering::SeqCst) {
            break;
        }
        let mut stream = match stream {
            Ok(s) => s,
            Err(_) => {
                // Persistent accept failures (EMFILE) must not spin.
                std::thread::sleep(Duration::from_millis(20));
                continue;
            }
        };
        let _ = stream.set_read_timeout(Some(shared.cfg.read_timeout));
        let _ = stream.set_write_timeout(Some(shared.cfg.write_timeout));
        if shared.active.load(Ordering::SeqCst) >= shared.cfg.max_connections {
            shared.logger.event(
                "metrics_http_rejected",
                &[("capacity", (shared.cfg.max_connections as u64).into())],
            );
            write_response(&mut stream, 503, "text/plain; charset=utf-8", "busy\n");
            let _ = stream.shutdown(Shutdown::Both);
            continue;
        }
        shared.active.fetch_add(1, Ordering::SeqCst);
        let conn_shared = Arc::clone(&shared);
        let handle = std::thread::spawn(move || {
            serve_one(stream, &conn_shared);
            conn_shared.active.fetch_sub(1, Ordering::SeqCst);
        });
        let mut threads = lock_unpoisoned(&shared.conn_threads);
        threads.retain(|t| !t.is_finished());
        threads.push(handle);
    }
}

/// Answer exactly one request on `stream`, then close. Every outcome —
/// including a torn or hostile head — produces at most one response
/// and a disconnect; nothing here can panic or block past the
/// configured timeouts.
fn serve_one(mut stream: TcpStream, shared: &Shared) {
    let (status, content_type, body) = match read_head(&mut stream, shared.cfg.max_request_bytes)
    {
        Ok(head) => route(&head, &shared.endpoints),
        Err(_) => (400, "text/plain; charset=utf-8", "bad request\n".to_string()),
    };
    if shared.logger.is_enabled() {
        shared.logger.event(
            "metrics_http_request",
            &[("status", u64::from(status).into()), ("bytes", (body.len() as u64).into())],
        );
    }
    write_response(&mut stream, status, content_type, &body);
    let _ = stream.shutdown(Shutdown::Both);
}

/// Read the request head (request line + headers) up to the byte cap.
/// Errors on a torn head, an over-cap head, or a read timeout.
fn read_head(stream: &mut TcpStream, cap: usize) -> std::io::Result<String> {
    let mut buf: Vec<u8> = Vec::with_capacity(512);
    let mut scratch = [0u8; 512];
    while !buf.windows(4).any(|w| w == b"\r\n\r\n") {
        if buf.len() >= cap {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                "request head exceeds cap",
            ));
        }
        let n = stream.read(&mut scratch)?;
        if n == 0 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "connection closed mid-request",
            ));
        }
        buf.extend_from_slice(&scratch[..n]);
    }
    Ok(String::from_utf8_lossy(&buf).into_owned())
}

/// Map a request head to `(status, content type, body)`. Headers are
/// deliberately ignored — only the request line matters for a scrape.
fn route(head: &str, endpoints: &HttpEndpoints) -> (u16, &'static str, String) {
    let line = head.lines().next().unwrap_or("");
    let mut parts = line.split_whitespace();
    let (method, path, version) = (
        parts.next().unwrap_or(""),
        parts.next().unwrap_or(""),
        parts.next().unwrap_or(""),
    );
    if method.is_empty() || path.is_empty() || !version.starts_with("HTTP/1.") {
        return (400, "text/plain; charset=utf-8", "bad request\n".to_string());
    }
    if method != "GET" {
        return (405, "text/plain; charset=utf-8", "method not allowed\n".to_string());
    }
    match path {
        "/metrics" => {
            (200, "text/plain; version=0.0.4; charset=utf-8", (endpoints.metrics)())
        }
        "/healthz" => (200, "application/json", (endpoints.healthz)()),
        _ => (404, "text/plain; charset=utf-8", "not found\n".to_string()),
    }
}

fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        503 => "Service Unavailable",
        _ => "Error",
    }
}

/// Write one complete HTTP/1.1 response; failures are swallowed (the
/// peer is gone, and observability must never take the plane down).
fn write_response(stream: &mut TcpStream, status: u16, content_type: &str, body: &str) {
    let mut head = format!(
        "HTTP/1.1 {status} {}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n",
        reason(status),
        body.len(),
    );
    if status == 405 {
        head.push_str("Allow: GET\r\n");
    }
    head.push_str("\r\n");
    let _ = stream.write_all(head.as_bytes());
    let _ = stream.write_all(body.as_bytes());
    let _ = stream.flush();
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_endpoints() -> HttpEndpoints {
        HttpEndpoints {
            metrics: Arc::new(|| "# TYPE up gauge\nup 1\n".to_string()),
            healthz: Arc::new(|| "{\"status\":\"ok\"}".to_string()),
        }
    }

    fn short_cfg() -> HttpConfig {
        HttpConfig {
            read_timeout: Duration::from_millis(300),
            write_timeout: Duration::from_millis(300),
            ..HttpConfig::default()
        }
    }

    /// One raw HTTP exchange: send `request`, read to EOF.
    fn exchange(addr: SocketAddr, request: &[u8]) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(request).unwrap();
        let mut out = Vec::new();
        let _ = s.read_to_end(&mut out);
        String::from_utf8_lossy(&out).into_owned()
    }

    #[test]
    fn serves_metrics_and_healthz_with_close_semantics() {
        let srv = HttpServer::start(
            "127.0.0.1:0",
            test_endpoints(),
            short_cfg(),
            Arc::new(JsonLogger::disabled()),
        )
        .unwrap();
        let resp = exchange(srv.local_addr(), b"GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(resp.starts_with("HTTP/1.1 200 OK\r\n"), "{resp}");
        assert!(resp.contains("Content-Type: text/plain; version=0.0.4; charset=utf-8\r\n"));
        assert!(resp.contains("Connection: close\r\n"));
        assert!(resp.ends_with("up 1\n"));
        let resp = exchange(srv.local_addr(), b"GET /healthz HTTP/1.0\r\n\r\n");
        assert!(resp.starts_with("HTTP/1.1 200 OK\r\n"), "{resp}");
        assert!(resp.contains("Content-Type: application/json\r\n"));
        assert!(resp.ends_with("{\"status\":\"ok\"}"));
        srv.shutdown();
    }

    #[test]
    fn content_length_matches_the_body() {
        let srv = HttpServer::start(
            "127.0.0.1:0",
            test_endpoints(),
            short_cfg(),
            Arc::new(JsonLogger::disabled()),
        )
        .unwrap();
        let resp = exchange(srv.local_addr(), b"GET /metrics HTTP/1.1\r\n\r\n");
        let (head, body) = resp.split_once("\r\n\r\n").expect("header/body split");
        let len: usize = head
            .lines()
            .find_map(|l| l.strip_prefix("Content-Length: "))
            .expect("content-length header")
            .parse()
            .unwrap();
        assert_eq!(len, body.len());
        srv.shutdown();
    }

    #[test]
    fn unknown_route_is_404_and_non_get_is_405() {
        let srv = HttpServer::start(
            "127.0.0.1:0",
            test_endpoints(),
            short_cfg(),
            Arc::new(JsonLogger::disabled()),
        )
        .unwrap();
        let resp = exchange(srv.local_addr(), b"GET /nope HTTP/1.1\r\n\r\n");
        assert!(resp.starts_with("HTTP/1.1 404 Not Found\r\n"), "{resp}");
        let resp = exchange(srv.local_addr(), b"POST /metrics HTTP/1.1\r\n\r\n");
        assert!(resp.starts_with("HTTP/1.1 405 Method Not Allowed\r\n"), "{resp}");
        assert!(resp.contains("Allow: GET\r\n"));
        srv.shutdown();
    }

    #[test]
    fn hostile_heads_get_400_not_a_hang() {
        let srv = HttpServer::start(
            "127.0.0.1:0",
            test_endpoints(),
            HttpConfig { max_request_bytes: 256, ..short_cfg() },
            Arc::new(JsonLogger::disabled()),
        )
        .unwrap();
        // Not an HTTP request line at all.
        let resp = exchange(srv.local_addr(), b"PQDTWNET garbage\r\n\r\n");
        assert!(resp.starts_with("HTTP/1.1 400 Bad Request\r\n"), "{resp}");
        // Head larger than the cap, never terminated.
        let big = vec![b'A'; 4096];
        let resp = exchange(srv.local_addr(), &big);
        assert!(resp.starts_with("HTTP/1.1 400 Bad Request\r\n"), "{resp}");
        // Torn head (peer closes before CRLFCRLF).
        let mut s = TcpStream::connect(srv.local_addr()).unwrap();
        s.write_all(b"GET /metr").unwrap();
        s.shutdown(Shutdown::Write).unwrap();
        let mut out = Vec::new();
        let _ = s.read_to_end(&mut out);
        let resp = String::from_utf8_lossy(&out);
        assert!(resp.starts_with("HTTP/1.1 400 Bad Request\r\n"), "{resp}");
        srv.shutdown();
    }

    #[test]
    fn connections_past_the_cap_get_503() {
        let srv = HttpServer::start(
            "127.0.0.1:0",
            test_endpoints(),
            HttpConfig { max_connections: 0, ..short_cfg() },
            Arc::new(JsonLogger::disabled()),
        )
        .unwrap();
        let resp = exchange(srv.local_addr(), b"GET /metrics HTTP/1.1\r\n\r\n");
        assert!(resp.starts_with("HTTP/1.1 503 Service Unavailable\r\n"), "{resp}");
        srv.shutdown();
    }

    #[test]
    fn route_parses_the_request_line_only() {
        let e = test_endpoints();
        assert_eq!(route("GET /metrics HTTP/1.1\r\n\r\n", &e).0, 200);
        assert_eq!(route("GET /healthz HTTP/1.1\r\nX-Junk: y\r\n\r\n", &e).0, 200);
        assert_eq!(route("GET /metrics/extra HTTP/1.1\r\n\r\n", &e).0, 404);
        assert_eq!(route("DELETE /metrics HTTP/1.1\r\n\r\n", &e).0, 405);
        assert_eq!(route("GET /metrics SPDY/3\r\n\r\n", &e).0, 400);
        assert_eq!(route("", &e).0, 400);
    }
}
