//! Blocking client for the `pqdtw` wire protocol: one TCP connection,
//! strict request/response alternation, connect and I/O timeouts.
//!
//! Server-side failures arrive as `Error` frames and surface as `Err`
//! from every method, so callers never have to pattern-match transport
//! failures apart from application ones. Callers that *do* care about
//! the failure flavor (the router's health machine, reconnect loops)
//! can classify with [`is_timeout_error`]: a read timeout means "slow
//! peer, the connection may still heal", while a decode failure means
//! "corrupt frame, drop the connection".

use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

use anyhow::{bail, ensure, Context, Result};

use crate::coordinator::Hit;
use crate::core::rng::Rng;
use crate::jobs::{JobEvent, JobResult, JobSnapshot, JobSpec};
use crate::nn::knn::PqQueryMode;
use crate::obs::QueryTrace;

use super::protocol::{self, NetRequest, NetResponse, WireStats};

/// Client-side timeouts.
#[derive(Debug, Clone, Copy)]
pub struct ClientConfig {
    /// TCP connect timeout (per resolved address).
    pub connect_timeout: Duration,
    /// Read/write timeout per frame.
    pub io_timeout: Duration,
}

impl Default for ClientConfig {
    fn default() -> Self {
        ClientConfig {
            connect_timeout: Duration::from_secs(5),
            io_timeout: Duration::from_secs(30),
        }
    }
}

/// Bounded-retry policy for [`connect_with_retry`]: up to `attempts`
/// connects separated by jittered exponential backoff.
#[derive(Debug, Clone, Copy)]
pub struct RetryConfig {
    /// Total connect attempts (>= 1).
    pub attempts: u32,
    /// Backoff before the second attempt; doubles per attempt.
    pub base_backoff: Duration,
    /// Backoff ceiling.
    pub max_backoff: Duration,
    /// Seed for the deterministic backoff jitter.
    pub jitter_seed: u64,
}

impl Default for RetryConfig {
    fn default() -> Self {
        RetryConfig {
            attempts: 5,
            base_backoff: Duration::from_millis(50),
            max_backoff: Duration::from_secs(2),
            jitter_seed: 0x9e37_79b9_7f4a_7c15,
        }
    }
}

/// The delay before retry number `attempt` (1-based): exponential
/// doubling from `base`, capped at `max`, then scaled by a uniform
/// jitter in `[0.5, 1.0]` so a fleet of clients retrying after the
/// same outage does not reconnect in lockstep.
pub fn jittered_backoff(base: Duration, max: Duration, attempt: u32, rng: &mut Rng) -> Duration {
    let exp = base.saturating_mul(1u32 << attempt.saturating_sub(1).min(16));
    let capped = exp.min(max);
    capped.mul_f64(0.5 + 0.5 * rng.uniform())
}

/// True when `err` is a transport timeout (a slow or stalled peer)
/// rather than a decode or protocol failure (a corrupt frame): some
/// `io::Error` in its chain reads `TimedOut` or `WouldBlock` (Unix
/// sockets report an expired `SO_RCVTIMEO` as the latter).
pub fn is_timeout_error(err: &anyhow::Error) -> bool {
    err.chain().any(|cause| {
        cause.downcast_ref::<std::io::Error>().is_some_and(|io| {
            matches!(
                io.kind(),
                std::io::ErrorKind::TimedOut | std::io::ErrorKind::WouldBlock
            )
        })
    })
}

/// [`Client::connect`] with bounded attempts and jittered exponential
/// backoff between them; returns the last connect error once the
/// attempt budget is spent.
pub fn connect_with_retry(addr: &str, cfg: ClientConfig, retry: RetryConfig) -> Result<Client> {
    ensure!(retry.attempts >= 1, "net: retry policy needs at least one attempt");
    // Fold the address into the jitter stream so concurrent dials to
    // different shards from one seed do not share a backoff schedule.
    let addr_salt = addr
        .bytes()
        .fold(0xcbf2_9ce4_8422_2325u64, |h, b| (h ^ u64::from(b)).wrapping_mul(0x100_0000_01b3));
    let mut rng = Rng::new(retry.jitter_seed ^ addr_salt);
    let mut last_err = None;
    for attempt in 1..=retry.attempts {
        if attempt > 1 {
            std::thread::sleep(jittered_backoff(
                retry.base_backoff,
                retry.max_backoff,
                attempt - 1,
                &mut rng,
            ));
        }
        match Client::connect(addr, cfg) {
            Ok(client) => return Ok(client),
            Err(e) => last_err = Some(e),
        }
    }
    let err = match last_err {
        Some(e) => e,
        // Unreachable (attempts >= 1), but degrade to an error rather
        // than panic in serving code.
        None => anyhow::anyhow!("net: no connect attempt was made"),
    };
    Err(err.context(format!("net: {addr} unreachable after {} attempts", retry.attempts)))
}

/// A 1-NN answer with its degraded-mode context (v4 trailer).
#[derive(Debug, Clone, PartialEq)]
pub struct NnReply {
    /// Database-global index of the nearest item.
    pub index: usize,
    /// Distance to it.
    pub distance: f64,
    /// Its label, when the database is labeled.
    pub label: Option<i64>,
    /// Present iff the request asked for a trace.
    pub trace: Option<QueryTrace>,
    /// True when one or more shards did not contribute.
    pub degraded: bool,
    /// The missing shard indices, ascending.
    pub missing_shards: Vec<u64>,
}

/// A top-k answer with its degraded-mode context (v4 trailer).
#[derive(Debug, Clone, PartialEq)]
pub struct TopKReply {
    /// Hits, ascending by `(distance, index)`.
    pub hits: Vec<Hit>,
    /// Present iff the request asked for a trace.
    pub trace: Option<QueryTrace>,
    /// True when one or more shards did not contribute.
    pub degraded: bool,
    /// The missing shard indices, ascending.
    pub missing_shards: Vec<u64>,
}

/// A connected `pqdtw` client.
pub struct Client {
    stream: TcpStream,
    max_frame_bytes: usize,
    /// Set after any transport-level failure (timeout, torn frame,
    /// unexpected EOF): the stream may no longer be on a frame
    /// boundary, and a late-arriving reply would be misattributed to
    /// the next request — so every further call fails fast instead.
    poisoned: bool,
}

impl Client {
    /// Connect to `addr` (host:port; tries each resolved address with
    /// the configured connect timeout).
    pub fn connect(addr: &str, cfg: ClientConfig) -> Result<Client> {
        let addrs: Vec<_> = addr
            .to_socket_addrs()
            .with_context(|| format!("net: resolving {addr}"))?
            .collect();
        let mut last_err = None;
        for sa in &addrs {
            match TcpStream::connect_timeout(sa, cfg.connect_timeout) {
                Ok(stream) => {
                    let _ = stream.set_nodelay(true);
                    stream
                        .set_read_timeout(Some(cfg.io_timeout))
                        .context("net: setting read timeout")?;
                    stream
                        .set_write_timeout(Some(cfg.io_timeout))
                        .context("net: setting write timeout")?;
                    return Ok(Client {
                        stream,
                        max_frame_bytes: protocol::MAX_FRAME_BYTES,
                        poisoned: false,
                    });
                }
                Err(e) => last_err = Some(e),
            }
        }
        match last_err {
            Some(e) => Err(e).with_context(|| format!("net: connecting to {addr}")),
            None => bail!("net: {addr} resolved to no addresses"),
        }
    }

    /// One request/response round trip. A transport failure poisons
    /// the connection: a reply that arrives after a timeout would
    /// otherwise be read as the answer to the *next* request.
    fn call(&mut self, req: &NetRequest) -> Result<NetResponse> {
        ensure!(
            !self.poisoned,
            "net: connection unusable after an earlier transport error (reconnect)"
        );
        if let Err(e) = protocol::write_frame(&mut self.stream, &protocol::encode_request(req)) {
            self.poisoned = true;
            return Err(e).context("net: sending request");
        }
        match protocol::read_frame(&mut self.stream, self.max_frame_bytes) {
            // A fully-read frame leaves the stream on a frame boundary
            // even if the payload fails to decode.
            Ok(Some((tag, payload))) => protocol::decode_response(tag, &payload),
            Ok(None) => {
                self.poisoned = true;
                bail!("net: server closed the connection")
            }
            Err(e) => {
                self.poisoned = true;
                Err(e)
            }
        }
    }

    /// One raw request/response round trip. The router's scatter path
    /// forwards already-decoded requests verbatim through this; `Error`
    /// frames come back as `Ok(NetResponse::Error(..))`, so transport
    /// health and application failures stay distinguishable.
    pub fn roundtrip(&mut self, req: &NetRequest) -> Result<NetResponse> {
        self.call(req)
    }

    /// True once a transport failure has made this connection unusable
    /// (every further call will fail fast; reconnect instead).
    pub fn is_poisoned(&self) -> bool {
        self.poisoned
    }

    /// Liveness round trip.
    pub fn ping(&mut self) -> Result<()> {
        match self.call(&NetRequest::Ping)? {
            NetResponse::Pong => Ok(()),
            NetResponse::Error(msg) => bail!("server error: {msg}"),
            other => bail!("net: unexpected response {other:?}"),
        }
    }

    /// Remote 1-NN query; answers bit-identically to the server
    /// engine's in-process `NnQuery`.
    pub fn nn(
        &mut self,
        series: &[f64],
        mode: PqQueryMode,
        nprobe: Option<usize>,
    ) -> Result<(usize, f64, Option<i64>)> {
        let (index, distance, label, _) = self.nn_traced(series, mode, nprobe, 0, false)?;
        Ok((index, distance, label))
    }

    /// [`Client::nn`] with a request id and an opt-in server-side
    /// [`QueryTrace`] (returned iff `trace` is set).
    pub fn nn_traced(
        &mut self,
        series: &[f64],
        mode: PqQueryMode,
        nprobe: Option<usize>,
        request_id: u64,
        trace: bool,
    ) -> Result<(usize, f64, Option<i64>, Option<QueryTrace>)> {
        let reply = self.nn_full(series, mode, nprobe, request_id, trace)?;
        Ok((reply.index, reply.distance, reply.label, reply.trace))
    }

    /// [`Client::nn_traced`] returning the full [`NnReply`], including
    /// the degraded-mode trailer a router may attach.
    pub fn nn_full(
        &mut self,
        series: &[f64],
        mode: PqQueryMode,
        nprobe: Option<usize>,
        request_id: u64,
        trace: bool,
    ) -> Result<NnReply> {
        let req =
            NetRequest::Nn { series: series.to_vec(), mode, nprobe, request_id, trace };
        match self.call(&req)? {
            NetResponse::Nn { index, distance, label, trace, degraded, missing_shards } => {
                Ok(NnReply { index, distance, label, trace, degraded, missing_shards })
            }
            NetResponse::Error(msg) => bail!("server error: {msg}"),
            other => bail!("net: unexpected response {other:?}"),
        }
    }

    /// Remote top-k query across the full serving-mode dial
    /// (exhaustive / IVF-probed / DTW re-ranked); answers
    /// bit-identically to the server engine's in-process `TopKQuery`.
    pub fn topk(
        &mut self,
        series: &[f64],
        k: usize,
        mode: PqQueryMode,
        nprobe: Option<usize>,
        rerank: Option<usize>,
    ) -> Result<Vec<Hit>> {
        let (hits, _) = self.topk_traced(series, k, mode, nprobe, rerank, 0, false)?;
        Ok(hits)
    }

    /// [`Client::topk`] with a request id and an opt-in server-side
    /// [`QueryTrace`] (returned iff `trace` is set).
    #[allow(clippy::too_many_arguments)]
    pub fn topk_traced(
        &mut self,
        series: &[f64],
        k: usize,
        mode: PqQueryMode,
        nprobe: Option<usize>,
        rerank: Option<usize>,
        request_id: u64,
        trace: bool,
    ) -> Result<(Vec<Hit>, Option<QueryTrace>)> {
        let reply = self.topk_full(series, k, mode, nprobe, rerank, request_id, trace)?;
        Ok((reply.hits, reply.trace))
    }

    /// [`Client::topk_traced`] returning the full [`TopKReply`],
    /// including the degraded-mode trailer a router may attach.
    #[allow(clippy::too_many_arguments)]
    pub fn topk_full(
        &mut self,
        series: &[f64],
        k: usize,
        mode: PqQueryMode,
        nprobe: Option<usize>,
        rerank: Option<usize>,
        request_id: u64,
        trace: bool,
    ) -> Result<TopKReply> {
        let req = NetRequest::TopK {
            series: series.to_vec(),
            k,
            mode,
            nprobe,
            rerank,
            request_id,
            trace,
        };
        match self.call(&req)? {
            NetResponse::TopK { hits, trace, degraded, missing_shards } => {
                Ok(TopKReply { hits, trace, degraded, missing_shards })
            }
            NetResponse::Error(msg) => bail!("server error: {msg}"),
            other => bail!("net: unexpected response {other:?}"),
        }
    }

    /// Fetch the server's metrics snapshot.
    pub fn stats(&mut self) -> Result<WireStats> {
        match self.call(&NetRequest::Stats)? {
            NetResponse::Stats(stats) => Ok(stats),
            NetResponse::Error(msg) => bail!("server error: {msg}"),
            other => bail!("net: unexpected response {other:?}"),
        }
    }

    /// Fetch the server's Prometheus text exposition document.
    pub fn metrics_text(&mut self) -> Result<String> {
        match self.call(&NetRequest::MetricsText)? {
            NetResponse::MetricsText(text) => Ok(text),
            NetResponse::Error(msg) => bail!("server error: {msg}"),
            other => bail!("net: unexpected response {other:?}"),
        }
    }

    /// Submit a durable background job; returns the server-assigned id.
    pub fn job_submit(&mut self, spec: JobSpec) -> Result<u64> {
        match self.call(&NetRequest::JobCreate { spec })? {
            NetResponse::JobCreated { id } => Ok(id),
            NetResponse::Error(msg) => bail!("server error: {msg}"),
            other => bail!("net: unexpected response {other:?}"),
        }
    }

    /// Current status/progress snapshot of a job.
    pub fn job_status(&mut self, id: u64) -> Result<JobSnapshot> {
        match self.call(&NetRequest::JobStatus { id })? {
            NetResponse::JobStatus(snap) => Ok(snap),
            NetResponse::Error(msg) => bail!("server error: {msg}"),
            other => bail!("net: unexpected response {other:?}"),
        }
    }

    /// Poll a job's progress events: those with `seq > cursor`, oldest
    /// first, at most `max` (capped at
    /// [`protocol::MAX_JOB_EVENTS`](super::protocol::MAX_JOB_EVENTS)).
    /// Also returns the newest retained sequence number, the natural
    /// next `cursor`.
    pub fn job_events(
        &mut self,
        id: u64,
        cursor: u64,
        max: usize,
    ) -> Result<(Vec<JobEvent>, u64)> {
        match self.call(&NetRequest::JobEvents { id, cursor, max })? {
            NetResponse::JobEvents { events, latest_seq } => Ok((events, latest_seq)),
            NetResponse::Error(msg) => bail!("server error: {msg}"),
            other => bail!("net: unexpected response {other:?}"),
        }
    }

    /// Request cancellation; the reply is the post-cancel status
    /// snapshot (a queued job is already `Cancelled`, a running job
    /// lands within one chunk boundary).
    pub fn job_cancel(&mut self, id: u64) -> Result<JobSnapshot> {
        match self.call(&NetRequest::JobCancel { id })? {
            NetResponse::JobStatus(snap) => Ok(snap),
            NetResponse::Error(msg) => bail!("server error: {msg}"),
            other => bail!("net: unexpected response {other:?}"),
        }
    }

    /// Fetch a completed job's persisted result.
    pub fn job_result(&mut self, id: u64) -> Result<JobResult> {
        match self.call(&NetRequest::JobResult { id })? {
            NetResponse::JobResult(result) => Ok(result),
            NetResponse::Error(msg) => bail!("server error: {msg}"),
            other => bail!("net: unexpected response {other:?}"),
        }
    }

    /// Ask the server to drain and exit; returns once acknowledged.
    pub fn shutdown(&mut self) -> Result<()> {
        match self.call(&NetRequest::Shutdown)? {
            NetResponse::ShutdownAck => Ok(()),
            NetResponse::Error(msg) => bail!("server error: {msg}"),
            other => bail!("net: unexpected response {other:?}"),
        }
    }
}
