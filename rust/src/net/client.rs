//! Blocking client for the `pqdtw` wire protocol: one TCP connection,
//! strict request/response alternation, connect and I/O timeouts.
//!
//! Server-side failures arrive as `Error` frames and surface as `Err`
//! from every method, so callers never have to pattern-match transport
//! failures apart from application ones.

use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

use anyhow::{bail, ensure, Context, Result};

use crate::coordinator::Hit;
use crate::jobs::{JobEvent, JobResult, JobSnapshot, JobSpec};
use crate::nn::knn::PqQueryMode;
use crate::obs::QueryTrace;

use super::protocol::{self, NetRequest, NetResponse, WireStats};

/// Client-side timeouts.
#[derive(Debug, Clone, Copy)]
pub struct ClientConfig {
    /// TCP connect timeout (per resolved address).
    pub connect_timeout: Duration,
    /// Read/write timeout per frame.
    pub io_timeout: Duration,
}

impl Default for ClientConfig {
    fn default() -> Self {
        ClientConfig {
            connect_timeout: Duration::from_secs(5),
            io_timeout: Duration::from_secs(30),
        }
    }
}

/// A connected `pqdtw` client.
pub struct Client {
    stream: TcpStream,
    max_frame_bytes: usize,
    /// Set after any transport-level failure (timeout, torn frame,
    /// unexpected EOF): the stream may no longer be on a frame
    /// boundary, and a late-arriving reply would be misattributed to
    /// the next request — so every further call fails fast instead.
    poisoned: bool,
}

impl Client {
    /// Connect to `addr` (host:port; tries each resolved address with
    /// the configured connect timeout).
    pub fn connect(addr: &str, cfg: ClientConfig) -> Result<Client> {
        let addrs: Vec<_> = addr
            .to_socket_addrs()
            .with_context(|| format!("net: resolving {addr}"))?
            .collect();
        let mut last_err = None;
        for sa in &addrs {
            match TcpStream::connect_timeout(sa, cfg.connect_timeout) {
                Ok(stream) => {
                    let _ = stream.set_nodelay(true);
                    stream
                        .set_read_timeout(Some(cfg.io_timeout))
                        .context("net: setting read timeout")?;
                    stream
                        .set_write_timeout(Some(cfg.io_timeout))
                        .context("net: setting write timeout")?;
                    return Ok(Client {
                        stream,
                        max_frame_bytes: protocol::MAX_FRAME_BYTES,
                        poisoned: false,
                    });
                }
                Err(e) => last_err = Some(e),
            }
        }
        match last_err {
            Some(e) => Err(e).with_context(|| format!("net: connecting to {addr}")),
            None => bail!("net: {addr} resolved to no addresses"),
        }
    }

    /// One request/response round trip. A transport failure poisons
    /// the connection: a reply that arrives after a timeout would
    /// otherwise be read as the answer to the *next* request.
    fn call(&mut self, req: &NetRequest) -> Result<NetResponse> {
        ensure!(
            !self.poisoned,
            "net: connection unusable after an earlier transport error (reconnect)"
        );
        if let Err(e) = protocol::write_frame(&mut self.stream, &protocol::encode_request(req)) {
            self.poisoned = true;
            return Err(e).context("net: sending request");
        }
        match protocol::read_frame(&mut self.stream, self.max_frame_bytes) {
            // A fully-read frame leaves the stream on a frame boundary
            // even if the payload fails to decode.
            Ok(Some((tag, payload))) => protocol::decode_response(tag, &payload),
            Ok(None) => {
                self.poisoned = true;
                bail!("net: server closed the connection")
            }
            Err(e) => {
                self.poisoned = true;
                Err(e)
            }
        }
    }

    /// Liveness round trip.
    pub fn ping(&mut self) -> Result<()> {
        match self.call(&NetRequest::Ping)? {
            NetResponse::Pong => Ok(()),
            NetResponse::Error(msg) => bail!("server error: {msg}"),
            other => bail!("net: unexpected response {other:?}"),
        }
    }

    /// Remote 1-NN query; answers bit-identically to the server
    /// engine's in-process `NnQuery`.
    pub fn nn(
        &mut self,
        series: &[f64],
        mode: PqQueryMode,
        nprobe: Option<usize>,
    ) -> Result<(usize, f64, Option<i64>)> {
        let (index, distance, label, _) = self.nn_traced(series, mode, nprobe, 0, false)?;
        Ok((index, distance, label))
    }

    /// [`Client::nn`] with a request id and an opt-in server-side
    /// [`QueryTrace`] (returned iff `trace` is set).
    pub fn nn_traced(
        &mut self,
        series: &[f64],
        mode: PqQueryMode,
        nprobe: Option<usize>,
        request_id: u64,
        trace: bool,
    ) -> Result<(usize, f64, Option<i64>, Option<QueryTrace>)> {
        let req =
            NetRequest::Nn { series: series.to_vec(), mode, nprobe, request_id, trace };
        match self.call(&req)? {
            NetResponse::Nn { index, distance, label, trace } => {
                Ok((index, distance, label, trace))
            }
            NetResponse::Error(msg) => bail!("server error: {msg}"),
            other => bail!("net: unexpected response {other:?}"),
        }
    }

    /// Remote top-k query across the full serving-mode dial
    /// (exhaustive / IVF-probed / DTW re-ranked); answers
    /// bit-identically to the server engine's in-process `TopKQuery`.
    pub fn topk(
        &mut self,
        series: &[f64],
        k: usize,
        mode: PqQueryMode,
        nprobe: Option<usize>,
        rerank: Option<usize>,
    ) -> Result<Vec<Hit>> {
        let (hits, _) = self.topk_traced(series, k, mode, nprobe, rerank, 0, false)?;
        Ok(hits)
    }

    /// [`Client::topk`] with a request id and an opt-in server-side
    /// [`QueryTrace`] (returned iff `trace` is set).
    #[allow(clippy::too_many_arguments)]
    pub fn topk_traced(
        &mut self,
        series: &[f64],
        k: usize,
        mode: PqQueryMode,
        nprobe: Option<usize>,
        rerank: Option<usize>,
        request_id: u64,
        trace: bool,
    ) -> Result<(Vec<Hit>, Option<QueryTrace>)> {
        let req = NetRequest::TopK {
            series: series.to_vec(),
            k,
            mode,
            nprobe,
            rerank,
            request_id,
            trace,
        };
        match self.call(&req)? {
            NetResponse::TopK { hits, trace } => Ok((hits, trace)),
            NetResponse::Error(msg) => bail!("server error: {msg}"),
            other => bail!("net: unexpected response {other:?}"),
        }
    }

    /// Fetch the server's metrics snapshot.
    pub fn stats(&mut self) -> Result<WireStats> {
        match self.call(&NetRequest::Stats)? {
            NetResponse::Stats(stats) => Ok(stats),
            NetResponse::Error(msg) => bail!("server error: {msg}"),
            other => bail!("net: unexpected response {other:?}"),
        }
    }

    /// Fetch the server's Prometheus text exposition document.
    pub fn metrics_text(&mut self) -> Result<String> {
        match self.call(&NetRequest::MetricsText)? {
            NetResponse::MetricsText(text) => Ok(text),
            NetResponse::Error(msg) => bail!("server error: {msg}"),
            other => bail!("net: unexpected response {other:?}"),
        }
    }

    /// Submit a durable background job; returns the server-assigned id.
    pub fn job_submit(&mut self, spec: JobSpec) -> Result<u64> {
        match self.call(&NetRequest::JobCreate { spec })? {
            NetResponse::JobCreated { id } => Ok(id),
            NetResponse::Error(msg) => bail!("server error: {msg}"),
            other => bail!("net: unexpected response {other:?}"),
        }
    }

    /// Current status/progress snapshot of a job.
    pub fn job_status(&mut self, id: u64) -> Result<JobSnapshot> {
        match self.call(&NetRequest::JobStatus { id })? {
            NetResponse::JobStatus(snap) => Ok(snap),
            NetResponse::Error(msg) => bail!("server error: {msg}"),
            other => bail!("net: unexpected response {other:?}"),
        }
    }

    /// Poll a job's progress events: those with `seq > cursor`, oldest
    /// first, at most `max` (capped at
    /// [`protocol::MAX_JOB_EVENTS`](super::protocol::MAX_JOB_EVENTS)).
    /// Also returns the newest retained sequence number, the natural
    /// next `cursor`.
    pub fn job_events(
        &mut self,
        id: u64,
        cursor: u64,
        max: usize,
    ) -> Result<(Vec<JobEvent>, u64)> {
        match self.call(&NetRequest::JobEvents { id, cursor, max })? {
            NetResponse::JobEvents { events, latest_seq } => Ok((events, latest_seq)),
            NetResponse::Error(msg) => bail!("server error: {msg}"),
            other => bail!("net: unexpected response {other:?}"),
        }
    }

    /// Request cancellation; the reply is the post-cancel status
    /// snapshot (a queued job is already `Cancelled`, a running job
    /// lands within one chunk boundary).
    pub fn job_cancel(&mut self, id: u64) -> Result<JobSnapshot> {
        match self.call(&NetRequest::JobCancel { id })? {
            NetResponse::JobStatus(snap) => Ok(snap),
            NetResponse::Error(msg) => bail!("server error: {msg}"),
            other => bail!("net: unexpected response {other:?}"),
        }
    }

    /// Fetch a completed job's persisted result.
    pub fn job_result(&mut self, id: u64) -> Result<JobResult> {
        match self.call(&NetRequest::JobResult { id })? {
            NetResponse::JobResult(result) => Ok(result),
            NetResponse::Error(msg) => bail!("server error: {msg}"),
            other => bail!("net: unexpected response {other:?}"),
        }
    }

    /// Ask the server to drain and exit; returns once acknowledged.
    pub fn shutdown(&mut self) -> Result<()> {
        match self.call(&NetRequest::Shutdown)? {
            NetResponse::ShutdownAck => Ok(()),
            NetResponse::Error(msg) => bail!("server error: {msg}"),
            other => bail!("net: unexpected response {other:?}"),
        }
    }
}
