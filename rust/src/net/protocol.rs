//! The `pqdtw` wire protocol: versioned, length-prefixed little-endian
//! frames over TCP (see `docs/wire-protocol.md` for the byte-level
//! specification and the version-bump policy).
//!
//! Every frame — request or response — is self-describing:
//!
//! ```text
//! magic    8 B   "PQDTWNET"
//! version  4 B   u32 LE (currently 1)
//! tag      1 B   frame kind
//! length   8 B   payload length in bytes, u64 LE
//! payload  …     tag-specific, encoded with the store's codec primitives
//! ```
//!
//! The payloads reuse [`crate::store::format`]'s `ByteWriter` /
//! `ByteReader`, inheriting its hardening discipline: every length
//! prefix is validated against the bytes actually present before any
//! allocation, so hostile frames (truncation, bit flips, `u64::MAX`
//! lengths, unknown tags, over-limit query lengths) yield `Err` —
//! never a panic, never an unbounded allocation. Unlike the on-disk
//! index there is no application checksum: TCP already protects frame
//! integrity in transit, and a flipped payload byte that still decodes
//! is indistinguishable from a different (valid) request, which the
//! engine answers or rejects like any other.

use std::io::{Read, Write};

use anyhow::{bail, ensure, Context, Result};

use crate::coordinator::Hit;
use crate::nn::knn::PqQueryMode;
use crate::store::format::{ByteReader, ByteWriter};

/// Magic bytes at offset 0 of every frame.
pub const NET_MAGIC: [u8; 8] = *b"PQDTWNET";

/// Current protocol version (any layout change increments this; peers
/// reject frames of versions they were not built to parse).
pub const NET_VERSION: u32 = 1;

/// Frame header size: magic + version + tag + payload length.
pub const HEADER_BYTES: usize = 8 + 4 + 1 + 8;

/// Default ceiling on one frame's payload, bounding what a hostile
/// length prefix can make a peer allocate (servers may configure a
/// smaller limit).
pub const MAX_FRAME_BYTES: usize = 8 << 20;

/// Semantic ceiling on query length in samples, far above any trained
/// series length — a request over this limit is rejected at decode
/// time, before the engine sees it.
pub const MAX_QUERY_LEN: usize = 1 << 20;

/// Request tags (1..=5).
pub const TAG_PING: u8 = 1;
/// 1-NN query.
pub const TAG_NN: u8 = 2;
/// Top-k query.
pub const TAG_TOPK: u8 = 3;
/// Metrics snapshot request.
pub const TAG_STATS: u8 = 4;
/// Graceful server shutdown request.
pub const TAG_SHUTDOWN: u8 = 5;

/// Response tags (64..).
pub const TAG_PONG: u8 = 64;
/// 1-NN result.
pub const TAG_NN_RESULT: u8 = 65;
/// Top-k result.
pub const TAG_TOPK_RESULT: u8 = 66;
/// Metrics snapshot.
pub const TAG_STATS_RESULT: u8 = 67;
/// Shutdown acknowledged; the server is draining.
pub const TAG_SHUTDOWN_ACK: u8 = 68;
/// Request failed; payload is a human-readable message.
pub const TAG_ERROR: u8 = 127;

/// A client-to-server frame.
#[derive(Debug, Clone, PartialEq)]
pub enum NetRequest {
    /// Liveness check.
    Ping,
    /// 1-NN query against the server's database.
    Nn {
        /// Raw query series (must match the index's trained length).
        series: Vec<f64>,
        /// Symmetric or asymmetric PQ distance.
        mode: PqQueryMode,
        /// Probe only the `n` nearest IVF cells.
        nprobe: Option<usize>,
    },
    /// Top-k query against the server's database.
    TopK {
        /// Raw query series.
        series: Vec<f64>,
        /// Neighbours to return.
        k: usize,
        /// Symmetric or asymmetric PQ distance.
        mode: PqQueryMode,
        /// Probe only the `n` nearest IVF cells.
        nprobe: Option<usize>,
        /// Re-rank this many PQ candidates with exact windowed DTW.
        rerank: Option<usize>,
    },
    /// Request the server's metrics snapshot.
    Stats,
    /// Ask the server to drain connections and exit.
    Shutdown,
}

/// One request class in a [`WireStats`] frame.
#[derive(Debug, Clone, PartialEq)]
pub struct WireClassStats {
    /// Index into [`crate::coordinator::RequestClass::ALL`].
    pub class: u8,
    /// Stable display name (self-describing across class additions).
    pub name: String,
    /// Requests served in this class.
    pub requests: u64,
    /// Mean latency (µs).
    pub mean_latency_us: f64,
    /// Median latency (µs, histogram bucket upper bound).
    pub p50_us: u64,
    /// 99th-percentile latency (µs, histogram bucket upper bound).
    pub p99_us: u64,
}

/// The server metrics snapshot as it crosses the wire.
#[derive(Debug, Clone, PartialEq)]
pub struct WireStats {
    /// Total requests served.
    pub requests: u64,
    /// Requests that returned an error.
    pub errors: u64,
    /// Batches executed.
    pub batches: u64,
    /// Mean batch size.
    pub mean_batch_size: f64,
    /// Mean latency (µs) across all classes.
    pub mean_latency_us: f64,
    /// Median latency (µs) across all classes.
    pub p50_us: u64,
    /// 99th-percentile latency (µs) across all classes.
    pub p99_us: u64,
    /// Per-request-class counters.
    pub per_class: Vec<WireClassStats>,
}

/// A server-to-client frame.
#[derive(Debug, Clone, PartialEq)]
pub enum NetResponse {
    /// Liveness reply.
    Pong,
    /// 1-NN result.
    Nn {
        /// Database index of the nearest item.
        index: usize,
        /// Distance to it.
        distance: f64,
        /// Its label, when the database is labeled.
        label: Option<i64>,
    },
    /// Ranked top-k result, ascending by distance.
    TopK(Vec<Hit>),
    /// Metrics snapshot.
    Stats(WireStats),
    /// Shutdown acknowledged; the connection closes after this frame.
    ShutdownAck,
    /// Request failed.
    Error(String),
}

/// On-wire tag of a [`PqQueryMode`].
fn mode_tag(m: PqQueryMode) -> u8 {
    match m {
        PqQueryMode::Symmetric => 0,
        PqQueryMode::Asymmetric => 1,
    }
}

/// [`PqQueryMode`] from its on-wire tag.
fn mode_from(tag: u8) -> Result<PqQueryMode> {
    match tag {
        0 => Ok(PqQueryMode::Symmetric),
        1 => Ok(PqQueryMode::Asymmetric),
        other => bail!("net: unknown query-mode tag {other}"),
    }
}

fn put_opt_i64(w: &mut ByteWriter, v: Option<i64>) {
    match v {
        Some(x) => {
            w.u8(1);
            w.bytes(&x.to_le_bytes());
        }
        None => w.u8(0),
    }
}

fn get_i64(r: &mut ByteReader) -> Result<i64> {
    let v = r.u64()?;
    Ok(i64::from_le_bytes(v.to_le_bytes()))
}

fn get_opt_i64(r: &mut ByteReader) -> Result<Option<i64>> {
    match r.u8()? {
        0 => Ok(None),
        1 => Ok(Some(get_i64(r)?)),
        other => bail!("net: bad option flag {other}"),
    }
}

/// Frame a payload: header (magic, version, tag, length) + payload.
pub fn encode_frame(tag: u8, payload: &[u8]) -> Vec<u8> {
    let mut w = ByteWriter::new();
    w.bytes(&NET_MAGIC);
    w.u32(NET_VERSION);
    w.u8(tag);
    w.usize(payload.len());
    w.bytes(payload);
    w.into_bytes()
}

/// Serialize a request into one wire frame.
pub fn encode_request(req: &NetRequest) -> Vec<u8> {
    let mut p = ByteWriter::new();
    let tag = match req {
        NetRequest::Ping => TAG_PING,
        NetRequest::Nn { series, mode, nprobe } => {
            p.u8(mode_tag(*mode));
            p.opt_usize(*nprobe);
            p.vec_f64(series);
            TAG_NN
        }
        NetRequest::TopK { series, k, mode, nprobe, rerank } => {
            p.usize(*k);
            p.u8(mode_tag(*mode));
            p.opt_usize(*nprobe);
            p.opt_usize(*rerank);
            p.vec_f64(series);
            TAG_TOPK
        }
        NetRequest::Stats => TAG_STATS,
        NetRequest::Shutdown => TAG_SHUTDOWN,
    };
    encode_frame(tag, &p.into_bytes())
}

/// Query series with the semantic length limit applied (the byte-level
/// count-vs-remaining check lives in `ByteReader::vec_f64`).
fn get_query_series(r: &mut ByteReader) -> Result<Vec<f64>> {
    let series = r.vec_f64()?;
    ensure!(
        series.len() <= MAX_QUERY_LEN,
        "net: query of {} samples exceeds the {MAX_QUERY_LEN}-sample limit",
        series.len()
    );
    ensure!(!series.is_empty(), "net: empty query series");
    Ok(series)
}

/// Deserialize and validate a request payload.
pub fn decode_request(tag: u8, payload: &[u8]) -> Result<NetRequest> {
    let mut r = ByteReader::new(payload);
    let req = match tag {
        TAG_PING => NetRequest::Ping,
        TAG_NN => {
            let mode = mode_from(r.u8()?)?;
            let nprobe = r.opt_usize()?;
            let series = get_query_series(&mut r)?;
            NetRequest::Nn { series, mode, nprobe }
        }
        TAG_TOPK => {
            let k = r.usize()?;
            ensure!(k >= 1, "net: k must be >= 1");
            let mode = mode_from(r.u8()?)?;
            let nprobe = r.opt_usize()?;
            let rerank = r.opt_usize()?;
            let series = get_query_series(&mut r)?;
            NetRequest::TopK { series, k, mode, nprobe, rerank }
        }
        TAG_STATS => NetRequest::Stats,
        TAG_SHUTDOWN => NetRequest::Shutdown,
        other => bail!("net: unknown request tag {other}"),
    };
    ensure!(r.is_exhausted(), "net: trailing bytes in request payload");
    Ok(req)
}

fn put_stats(w: &mut ByteWriter, s: &WireStats) {
    w.u64(s.requests);
    w.u64(s.errors);
    w.u64(s.batches);
    w.f64(s.mean_batch_size);
    w.f64(s.mean_latency_us);
    w.u64(s.p50_us);
    w.u64(s.p99_us);
    w.usize(s.per_class.len());
    for c in &s.per_class {
        w.u8(c.class);
        w.string(&c.name);
        w.u64(c.requests);
        w.f64(c.mean_latency_us);
        w.u64(c.p50_us);
        w.u64(c.p99_us);
    }
}

fn get_stats(r: &mut ByteReader) -> Result<WireStats> {
    let requests = r.u64()?;
    let errors = r.u64()?;
    let batches = r.u64()?;
    let mean_batch_size = r.f64()?;
    let mean_latency_us = r.f64()?;
    let p50_us = r.u64()?;
    let p99_us = r.u64()?;
    let n = r.usize()?;
    // Each class entry holds at least tag + name length + counters, so
    // any count claiming more than the remaining bytes could encode is
    // hostile — reject before reserving capacity.
    ensure!(
        n.saturating_mul(41) <= r.remaining(),
        "net: stats class count {n} exceeds remaining frame bytes"
    );
    let mut per_class = Vec::with_capacity(n);
    for _ in 0..n {
        per_class.push(WireClassStats {
            class: r.u8()?,
            name: r.string()?,
            requests: r.u64()?,
            mean_latency_us: r.f64()?,
            p50_us: r.u64()?,
            p99_us: r.u64()?,
        });
    }
    Ok(WireStats {
        requests,
        errors,
        batches,
        mean_batch_size,
        mean_latency_us,
        p50_us,
        p99_us,
        per_class,
    })
}

/// Serialize a response into one wire frame.
pub fn encode_response(resp: &NetResponse) -> Vec<u8> {
    let mut p = ByteWriter::new();
    let tag = match resp {
        NetResponse::Pong => TAG_PONG,
        NetResponse::Nn { index, distance, label } => {
            p.usize(*index);
            p.f64(*distance);
            put_opt_i64(&mut p, *label);
            TAG_NN_RESULT
        }
        NetResponse::TopK(hits) => {
            p.usize(hits.len());
            for h in hits {
                p.usize(h.index);
                p.f64(h.distance);
                put_opt_i64(&mut p, h.label);
            }
            TAG_TOPK_RESULT
        }
        NetResponse::Stats(s) => {
            put_stats(&mut p, s);
            TAG_STATS_RESULT
        }
        NetResponse::ShutdownAck => TAG_SHUTDOWN_ACK,
        NetResponse::Error(msg) => {
            p.string(msg);
            TAG_ERROR
        }
    };
    encode_frame(tag, &p.into_bytes())
}

/// Deserialize and validate a response payload.
pub fn decode_response(tag: u8, payload: &[u8]) -> Result<NetResponse> {
    let mut r = ByteReader::new(payload);
    let resp = match tag {
        TAG_PONG => NetResponse::Pong,
        TAG_NN_RESULT => {
            let index = r.usize()?;
            let distance = r.f64()?;
            let label = get_opt_i64(&mut r)?;
            NetResponse::Nn { index, distance, label }
        }
        TAG_TOPK_RESULT => {
            let n = r.usize()?;
            // index + distance + label presence byte = ≥ 17 B per hit
            ensure!(
                n.saturating_mul(17) <= r.remaining(),
                "net: hit count {n} exceeds remaining frame bytes"
            );
            let mut hits = Vec::with_capacity(n);
            for _ in 0..n {
                let index = r.usize()?;
                let distance = r.f64()?;
                let label = get_opt_i64(&mut r)?;
                hits.push(Hit { index, distance, label });
            }
            NetResponse::TopK(hits)
        }
        TAG_STATS_RESULT => NetResponse::Stats(get_stats(&mut r)?),
        TAG_SHUTDOWN_ACK => NetResponse::ShutdownAck,
        TAG_ERROR => NetResponse::Error(r.string()?),
        other => bail!("net: unknown response tag {other}"),
    };
    ensure!(r.is_exhausted(), "net: trailing bytes in response payload");
    Ok(resp)
}

/// Read one frame from a stream. `Ok(None)` means a clean EOF at a
/// frame boundary (the peer closed between frames). A malformed header
/// or an over-limit length is an `Err`; the stream can no longer be
/// assumed frame-synchronized and the caller should drop it.
pub fn read_frame(r: &mut impl Read, max_frame_bytes: usize) -> Result<Option<(u8, Vec<u8>)>> {
    let mut header = [0u8; HEADER_BYTES];
    // Read the first byte separately so EOF at a frame boundary is
    // distinguishable from a frame torn mid-header.
    let n = loop {
        match r.read(&mut header[..1]) {
            Ok(n) => break n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e).context("net: reading frame header"),
        }
    };
    if n == 0 {
        return Ok(None);
    }
    r.read_exact(&mut header[1..]).context("net: truncated frame header")?;
    // The header buffer always holds HEADER_BYTES, so these reads
    // cannot fail — but they propagate rather than panic regardless.
    let mut h = ByteReader::new(&header);
    let magic = h.take(8)?;
    ensure!(
        magic == &NET_MAGIC[..],
        "net: bad frame magic {magic:02x?} (not a pqdtw peer?)"
    );
    let version = h.u32()?;
    ensure!(
        version == NET_VERSION,
        "net: unsupported protocol version {version} (this build speaks {NET_VERSION})"
    );
    let tag = h.u8()?;
    let len = h.u64()?;
    ensure!(
        len <= max_frame_bytes as u64,
        "net: frame of {len} bytes exceeds the {max_frame_bytes}-byte limit"
    );
    let len = usize::try_from(len).context("net: frame length exceeds usize")?;
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload).context("net: truncated frame payload")?;
    Ok(Some((tag, payload)))
}

/// Write one pre-encoded frame and flush it.
pub fn write_frame(w: &mut impl Write, frame: &[u8]) -> std::io::Result<()> {
    w.write_all(frame)?;
    w.flush()
}

/// Decode a request from a complete, exact frame byte buffer (the
/// hostile-frame sweep drives this; live connections use
/// [`read_frame`] + [`decode_request`]).
pub fn decode_request_bytes(bytes: &[u8]) -> Result<NetRequest> {
    let mut cursor = std::io::Cursor::new(bytes);
    match read_frame(&mut cursor, MAX_FRAME_BYTES)? {
        None => bail!("net: empty frame buffer"),
        Some((tag, payload)) => {
            ensure!(
                cursor.position() == bytes.len() as u64,
                "net: trailing bytes after frame"
            );
            decode_request(tag, &payload)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_requests() -> Vec<NetRequest> {
        vec![
            NetRequest::Ping,
            NetRequest::Stats,
            NetRequest::Shutdown,
            NetRequest::Nn {
                series: vec![0.25, -1.5, f64::NAN, 3.0],
                mode: PqQueryMode::Symmetric,
                nprobe: Some(4),
            },
            NetRequest::TopK {
                series: vec![1.0; 16],
                k: 5,
                mode: PqQueryMode::Asymmetric,
                nprobe: None,
                rerank: Some(20),
            },
        ]
    }

    fn sample_responses() -> Vec<NetResponse> {
        vec![
            NetResponse::Pong,
            NetResponse::ShutdownAck,
            NetResponse::Error("nope".into()),
            NetResponse::Nn { index: 7, distance: 1.25, label: Some(-3) },
            NetResponse::TopK(vec![
                Hit { index: 0, distance: 0.5, label: None },
                Hit { index: 9, distance: 0.75, label: Some(2) },
            ]),
            NetResponse::Stats(WireStats {
                requests: 10,
                errors: 1,
                batches: 4,
                mean_batch_size: 2.5,
                mean_latency_us: 120.0,
                p50_us: 100,
                p99_us: 1000,
                per_class: vec![WireClassStats {
                    class: 3,
                    name: "topk_exhaustive".into(),
                    requests: 10,
                    mean_latency_us: 120.0,
                    p50_us: 100,
                    p99_us: 1000,
                }],
            }),
        ]
    }

    fn roundtrip_request(req: &NetRequest) -> NetRequest {
        decode_request_bytes(&encode_request(req)).unwrap()
    }

    #[test]
    fn request_roundtrip_is_exact() {
        for req in sample_requests() {
            let back = roundtrip_request(&req);
            // NaN breaks PartialEq; compare the NaN-carrying request by
            // bit pattern instead.
            if let (
                NetRequest::Nn { series: a, .. },
                NetRequest::Nn { series: b, .. },
            ) = (&req, &back)
            {
                let a: Vec<u64> = a.iter().map(|x| x.to_bits()).collect();
                let b: Vec<u64> = b.iter().map(|x| x.to_bits()).collect();
                assert_eq!(a, b);
            } else {
                assert_eq!(req, back);
            }
        }
    }

    #[test]
    fn response_roundtrip_is_exact() {
        for resp in sample_responses() {
            let frame = encode_response(&resp);
            let mut cursor = std::io::Cursor::new(&frame[..]);
            let (tag, payload) = read_frame(&mut cursor, MAX_FRAME_BYTES).unwrap().unwrap();
            assert_eq!(decode_response(tag, &payload).unwrap(), resp);
        }
    }

    #[test]
    fn clean_eof_is_none_and_torn_header_is_err() {
        let mut empty: &[u8] = &[];
        assert!(read_frame(&mut empty, MAX_FRAME_BYTES).unwrap().is_none());
        let frame = encode_request(&NetRequest::Ping);
        let mut torn = &frame[..HEADER_BYTES - 3];
        assert!(read_frame(&mut torn, MAX_FRAME_BYTES).is_err());
    }

    #[test]
    fn bad_magic_version_tag_and_length_are_rejected() {
        let good = encode_request(&NetRequest::Ping);

        let mut bad_magic = good.clone();
        bad_magic[0] ^= 0xff;
        assert!(decode_request_bytes(&bad_magic).is_err());

        let mut bad_version = good.clone();
        bad_version[8..12].copy_from_slice(&999u32.to_le_bytes());
        let err = decode_request_bytes(&bad_version).unwrap_err().to_string();
        assert!(err.contains("version 999"), "{err}");

        let mut bad_tag = good.clone();
        bad_tag[12] = 200;
        assert!(decode_request_bytes(&bad_tag).is_err());

        // A u64::MAX length claim must be rejected by the frame-size
        // limit before any allocation happens.
        let mut huge_len = good;
        huge_len[13..21].copy_from_slice(&u64::MAX.to_le_bytes());
        let err = decode_request_bytes(&huge_len).unwrap_err().to_string();
        assert!(err.contains("exceeds"), "{err}");
    }

    #[test]
    fn over_limit_query_length_is_rejected() {
        // Forge a TopK payload claiming MAX_QUERY_LEN + 1 samples. The
        // byte-level count check fires first (the frame cannot back the
        // claim), which is exactly the no-unbounded-allocation property.
        let mut p = ByteWriter::new();
        p.usize(3); // k
        p.u8(1); // asymmetric
        p.u8(0); // nprobe: None
        p.u8(0); // rerank: None
        p.usize(MAX_QUERY_LEN + 1); // series length prefix, no data
        let frame = encode_frame(TAG_TOPK, &p.into_bytes());
        assert!(decode_request_bytes(&frame).is_err());
    }

    #[test]
    fn empty_query_and_zero_k_are_rejected() {
        let mut p = ByteWriter::new();
        p.u8(0); // symmetric
        p.u8(0); // nprobe: None
        p.usize(0); // empty series
        let frame = encode_frame(TAG_NN, &p.into_bytes());
        assert!(decode_request_bytes(&frame).is_err());

        let mut p = ByteWriter::new();
        p.usize(0); // k = 0
        p.u8(0);
        p.u8(0);
        p.u8(0);
        p.usize(0);
        let frame = encode_frame(TAG_TOPK, &p.into_bytes());
        assert!(decode_request_bytes(&frame).is_err());
    }

    #[test]
    fn trailing_bytes_are_rejected() {
        let mut frame = encode_request(&NetRequest::Ping);
        frame.push(0);
        assert!(decode_request_bytes(&frame).is_err());
    }

    /// Under Miri each decode is orders of magnitude slower; stride the
    /// exhaustive hostile sweeps so the UB check still samples every
    /// region in reasonable time. Native runs stay exhaustive.
    fn sweep_stride() -> usize {
        if cfg!(miri) {
            13 // prime relative to the 21-byte header and 8-byte fields
        } else {
            1
        }
    }

    #[test]
    fn hostile_sweep_never_panics_or_overallocates() {
        // Every prefix truncation and every single-byte flip of a valid
        // request frame must decode to Err or to some in-limit request —
        // never panic, never allocate beyond the frame limit. (A payload
        // flip can legitimately decode to a *different* valid request;
        // TCP checksums own in-transit integrity.)
        let good = encode_request(&NetRequest::TopK {
            series: vec![0.5; 24],
            k: 3,
            mode: PqQueryMode::Asymmetric,
            nprobe: Some(2),
            rerank: Some(9),
        });
        for n in (0..good.len()).step_by(sweep_stride()) {
            let _ = decode_request_bytes(&good[..n]);
        }
        for i in (0..good.len()).step_by(sweep_stride()) {
            for bit in [0x01u8, 0x40, 0x80] {
                let mut bad = good.clone();
                bad[i] ^= bit;
                if let Ok(req) = decode_request_bytes(&bad) {
                    match req {
                        NetRequest::Nn { series, .. }
                        | NetRequest::TopK { series, .. } => {
                            assert!(series.len() <= MAX_QUERY_LEN)
                        }
                        NetRequest::Ping | NetRequest::Stats | NetRequest::Shutdown => {}
                    }
                }
            }
        }
    }

    #[test]
    fn response_sweep_never_panics() {
        for resp in sample_responses() {
            let good = encode_response(&resp);
            for n in (0..good.len()).step_by(sweep_stride()) {
                let mut cursor = std::io::Cursor::new(&good[..n]);
                if let Ok(Some((tag, payload))) = read_frame(&mut cursor, MAX_FRAME_BYTES) {
                    let _ = decode_response(tag, &payload);
                }
            }
            for i in (0..good.len()).step_by(sweep_stride()) {
                let mut bad = good.clone();
                bad[i] ^= 0x40;
                let mut cursor = std::io::Cursor::new(&bad[..]);
                if let Ok(Some((tag, payload))) = read_frame(&mut cursor, MAX_FRAME_BYTES) {
                    let _ = decode_response(tag, &payload);
                }
            }
        }
    }

    #[test]
    fn hostile_stats_and_hit_counts_are_rejected_without_allocating() {
        let mut p = ByteWriter::new();
        p.usize(usize::MAX); // hit count
        let frame = encode_frame(TAG_TOPK_RESULT, &p.into_bytes());
        let mut cursor = std::io::Cursor::new(&frame[..]);
        let (tag, payload) = read_frame(&mut cursor, MAX_FRAME_BYTES).unwrap().unwrap();
        assert!(decode_response(tag, &payload).is_err());

        let mut p = ByteWriter::new();
        for _ in 0..7 {
            p.u64(0); // counters through p99
        }
        p.usize(1 << 60); // class count
        let frame = encode_frame(TAG_STATS_RESULT, &p.into_bytes());
        let mut cursor = std::io::Cursor::new(&frame[..]);
        let (tag, payload) = read_frame(&mut cursor, MAX_FRAME_BYTES).unwrap().unwrap();
        assert!(decode_response(tag, &payload).is_err());
    }
}
