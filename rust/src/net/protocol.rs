//! The `pqdtw` wire protocol: versioned, length-prefixed little-endian
//! frames over TCP (see `docs/wire-protocol.md` for the byte-level
//! specification and the version-bump policy).
//!
//! Every frame — request or response — is self-describing:
//!
//! ```text
//! magic    8 B   "PQDTWNET"
//! version  4 B   u32 LE (currently 5)
//! tag      1 B   frame kind
//! length   8 B   payload length in bytes, u64 LE
//! payload  …     tag-specific, encoded with the store's codec primitives
//! ```
//!
//! The payloads reuse [`crate::store::format`]'s `ByteWriter` /
//! `ByteReader`, inheriting its hardening discipline: every length
//! prefix is validated against the bytes actually present before any
//! allocation, so hostile frames (truncation, bit flips, `u64::MAX`
//! lengths, unknown tags, over-limit query lengths) yield `Err` —
//! never a panic, never an unbounded allocation. Unlike the on-disk
//! index there is no application checksum: TCP already protects frame
//! integrity in transit, and a flipped payload byte that still decodes
//! is indistinguishable from a different (valid) request, which the
//! engine answers or rejects like any other.

use std::io::{Read, Write};

use anyhow::{bail, ensure, Context, Result};

use crate::coordinator::Hit;
use crate::jobs::{JobEvent, JobSnapshot, JobSpec};
use crate::nn::knn::PqQueryMode;
use crate::obs::{ChildTrace, HitExplain, QueryTrace, ScanSnapshot, Stage, StageSpan};
use crate::store::format::{ByteReader, ByteWriter};
use crate::store::jobs as jobs_codec;

/// Magic bytes at offset 0 of every frame.
pub const NET_MAGIC: [u8; 8] = *b"PQDTWNET";

/// Current protocol version (any layout change increments this; peers
/// reject frames of versions they were not built to parse).
///
/// v2 added request ids + the `trace` flag on `Nn`/`TopK`, the optional
/// [`QueryTrace`] trailer on their results, the `MetricsText` frame
/// pair, and the uptime/version/index-header/per-stage extension of
/// [`WireStats`].
///
/// v3 added the job-plane frames: `JobCreate`/`JobStatus`/`JobEvents`
/// (cursor-based poll)/`JobCancel`/`JobResult` requests and their
/// responses (`JobCancel` is answered with a `JobStatus` result frame).
///
/// v4 added the degraded-mode trailer on `Nn`/`TopK` results: a
/// `degraded` flag plus the sorted list of shard indices that did not
/// contribute, appended after the optional trace so a scatter-gather
/// router ([`crate::router`]) can surface partial answers explicitly.
/// Single-node servers always send `degraded = false` with an empty
/// list.
///
/// v5 made the observability plane topology-aware: `Nn`/`TopK` traces
/// gained an optional per-hit shard provenance field and a trailing
/// list of per-shard child traces (depth 1 — a child may not itself
/// carry children), and [`WireStats`] gained raw per-bucket histogram
/// counts (total, per-class, and per-stage, aligned with
/// [`crate::coordinator::BUCKETS_US`]) so a router can merge fleet
/// percentiles exactly instead of approximating.
pub const NET_VERSION: u32 = 5;

/// Frame header size: magic + version + tag + payload length.
pub const HEADER_BYTES: usize = 8 + 4 + 1 + 8;

/// Default ceiling on one frame's payload, bounding what a hostile
/// length prefix can make a peer allocate (servers may configure a
/// smaller limit).
pub const MAX_FRAME_BYTES: usize = 8 << 20;

/// Semantic ceiling on query length in samples, far above any trained
/// series length — a request over this limit is rejected at decode
/// time, before the engine sees it.
pub const MAX_QUERY_LEN: usize = 1 << 20;

/// Latency histograms cross the wire as exactly this many raw `u64`
/// per-bucket counts, one per [`crate::coordinator::BUCKETS_US`]
/// bound — fixed-size, so there is no length prefix to validate.
pub const N_LATENCY_BUCKETS: usize = 12;

// The wire layout is pinned to the metrics plane's bucket ladder; a
// bucket change is a protocol version bump.
const _: () = assert!(crate::coordinator::metrics::BUCKETS_US.len() == N_LATENCY_BUCKETS);

/// Request tags (1..=11).
pub const TAG_PING: u8 = 1;
/// 1-NN query.
pub const TAG_NN: u8 = 2;
/// Top-k query.
pub const TAG_TOPK: u8 = 3;
/// Metrics snapshot request.
pub const TAG_STATS: u8 = 4;
/// Graceful server shutdown request.
pub const TAG_SHUTDOWN: u8 = 5;
/// Prometheus text exposition request.
pub const TAG_METRICS_TEXT: u8 = 6;
/// Submit a job (payload: a job spec).
pub const TAG_JOB_CREATE: u8 = 7;
/// Poll a job's status snapshot.
pub const TAG_JOB_STATUS: u8 = 8;
/// Poll a job's progress events past a cursor.
pub const TAG_JOB_EVENTS: u8 = 9;
/// Request job cancellation (answered with a status snapshot).
pub const TAG_JOB_CANCEL: u8 = 10;
/// Fetch a completed job's result payload.
pub const TAG_JOB_RESULT: u8 = 11;

/// Response tags (64..).
pub const TAG_PONG: u8 = 64;
/// 1-NN result.
pub const TAG_NN_RESULT: u8 = 65;
/// Top-k result.
pub const TAG_TOPK_RESULT: u8 = 66;
/// Metrics snapshot.
pub const TAG_STATS_RESULT: u8 = 67;
/// Shutdown acknowledged; the server is draining.
pub const TAG_SHUTDOWN_ACK: u8 = 68;
/// Prometheus text exposition document.
pub const TAG_METRICS_TEXT_RESULT: u8 = 69;
/// Job accepted; payload is its id.
pub const TAG_JOB_CREATED: u8 = 70;
/// Job status snapshot (also the answer to a cancel request).
pub const TAG_JOB_STATUS_RESULT: u8 = 71;
/// Job progress events past the polled cursor.
pub const TAG_JOB_EVENTS_RESULT: u8 = 72;
/// Completed job's result payload.
pub const TAG_JOB_RESULT_RESULT: u8 = 73;
/// Request failed; payload is a human-readable message.
pub const TAG_ERROR: u8 = 127;

/// A client-to-server frame.
#[derive(Debug, Clone, PartialEq)]
pub enum NetRequest {
    /// Liveness check.
    Ping,
    /// 1-NN query against the server's database.
    Nn {
        /// Raw query series (must match the index's trained length).
        series: Vec<f64>,
        /// Symmetric or asymmetric PQ distance.
        mode: PqQueryMode,
        /// Probe only the `n` nearest IVF cells.
        nprobe: Option<usize>,
        /// Client-chosen id echoed back in the result's trace
        /// (0 when the client does not correlate requests).
        request_id: u64,
        /// Return a [`QueryTrace`] with per-hit explanations.
        trace: bool,
    },
    /// Top-k query against the server's database.
    TopK {
        /// Raw query series.
        series: Vec<f64>,
        /// Neighbours to return.
        k: usize,
        /// Symmetric or asymmetric PQ distance.
        mode: PqQueryMode,
        /// Probe only the `n` nearest IVF cells.
        nprobe: Option<usize>,
        /// Re-rank this many PQ candidates with exact windowed DTW.
        rerank: Option<usize>,
        /// Client-chosen id echoed back in the result's trace.
        request_id: u64,
        /// Return a [`QueryTrace`] with per-hit explanations.
        trace: bool,
    },
    /// Request the server's metrics snapshot.
    Stats,
    /// Request the Prometheus text exposition document.
    MetricsText,
    /// Submit a job to the server's job plane.
    JobCreate {
        /// The job kind and its parameters.
        spec: JobSpec,
    },
    /// Poll a job's status snapshot.
    JobStatus {
        /// Job id from `JobCreated`.
        id: u64,
    },
    /// Poll a job's progress events with `seq > cursor`.
    JobEvents {
        /// Job id from `JobCreated`.
        id: u64,
        /// Return only events newer than this sequence number
        /// (0 = from the start of the retained window).
        cursor: u64,
        /// At most this many events (1 ..= [`MAX_JOB_EVENTS`]).
        max: usize,
    },
    /// Request cancellation; the answer is a status snapshot.
    JobCancel {
        /// Job id from `JobCreated`.
        id: u64,
    },
    /// Fetch a completed job's result payload (an `Error` frame while
    /// the job is not yet completed).
    JobResult {
        /// Job id from `JobCreated`.
        id: u64,
    },
    /// Ask the server to drain connections and exit.
    Shutdown,
}

/// Ceiling on the `max` field of a `JobEvents` poll — far above the
/// per-job retention window, so one poll can always drain it, while a
/// hostile value is rejected at decode time.
pub const MAX_JOB_EVENTS: usize = 4096;

/// One request class in a [`WireStats`] frame.
#[derive(Debug, Clone, PartialEq)]
pub struct WireClassStats {
    /// Index into [`crate::coordinator::RequestClass::ALL`].
    pub class: u8,
    /// Stable display name (self-describing across class additions).
    pub name: String,
    /// Requests served in this class.
    pub requests: u64,
    /// Mean latency (µs).
    pub mean_latency_us: f64,
    /// Median latency (µs, histogram bucket upper bound).
    pub p50_us: u64,
    /// 99th-percentile latency (µs, histogram bucket upper bound).
    pub p99_us: u64,
    /// Raw per-bucket histogram counts, one per
    /// [`crate::coordinator::BUCKETS_US`] bound (exactly
    /// [`N_LATENCY_BUCKETS`] entries).
    pub buckets: Vec<u64>,
}

/// One query-ladder stage in a [`WireStats`] frame.
#[derive(Debug, Clone, PartialEq)]
pub struct WireStageStats {
    /// Stable stage discriminant ([`Stage::as_u8`]).
    pub stage: u8,
    /// Stable display name ([`Stage::name`]).
    pub name: String,
    /// Spans recorded for this stage.
    pub count: u64,
    /// Mean stage wall-time (µs).
    pub mean_us: f64,
    /// Median stage wall-time (µs, histogram bucket upper bound).
    pub p50_us: u64,
    /// 99th-percentile stage wall-time (µs, bucket upper bound).
    pub p99_us: u64,
    /// Raw per-bucket histogram counts, one per
    /// [`crate::coordinator::BUCKETS_US`] bound (exactly
    /// [`N_LATENCY_BUCKETS`] entries).
    pub buckets: Vec<u64>,
}

/// The server metrics snapshot as it crosses the wire.
#[derive(Debug, Clone, PartialEq)]
pub struct WireStats {
    /// Total requests served.
    pub requests: u64,
    /// Requests that returned an error.
    pub errors: u64,
    /// Batches executed.
    pub batches: u64,
    /// Mean batch size.
    pub mean_batch_size: f64,
    /// Mean latency (µs) across all classes.
    pub mean_latency_us: f64,
    /// Median latency (µs) across all classes.
    pub p50_us: u64,
    /// 99th-percentile latency (µs) across all classes.
    pub p99_us: u64,
    /// Raw per-bucket histogram counts across all classes, one per
    /// [`crate::coordinator::BUCKETS_US`] bound (exactly
    /// [`N_LATENCY_BUCKETS`] entries) — the lossless form the router's
    /// exact percentile federation merges.
    pub latency_buckets: Vec<u64>,
    /// Per-request-class counters.
    pub per_class: Vec<WireClassStats>,
    /// Per-ladder-stage latency counters.
    pub per_stage: Vec<WireStageStats>,
    /// Engine-wide prune-cascade counters since server start.
    pub scan: ScanSnapshot,
    /// Whole seconds since the server started.
    pub uptime_s: u64,
    /// Server crate version (`CARGO_PKG_VERSION`).
    pub version: String,
    /// Index header summary: items in the database.
    pub n_items: u64,
    /// PQ subspaces (`M`).
    pub n_subspaces: u64,
    /// Centroids per subspace (`K`).
    pub codebook_size: u64,
    /// Trained series length (`L`).
    pub series_len: u64,
    /// Sakoe-Chiba window fraction.
    pub window_frac: f64,
    /// Coarse quantizer metric (`dtw` / `euclidean` / `none`).
    pub coarse_metric: String,
    /// IVF coarse cells, when an IVF index is attached.
    pub nlist: Option<usize>,
}

/// A server-to-client frame.
#[derive(Debug, Clone, PartialEq)]
pub enum NetResponse {
    /// Liveness reply.
    Pong,
    /// 1-NN result.
    Nn {
        /// Database index of the nearest item.
        index: usize,
        /// Distance to it.
        distance: f64,
        /// Its label, when the database is labeled.
        label: Option<i64>,
        /// Present iff the request set its `trace` flag.
        trace: Option<QueryTrace>,
        /// True when the answer covers only part of the database (one
        /// or more shards were unreachable). Always false from a
        /// single-node server.
        degraded: bool,
        /// Shard indices that did not contribute, ascending (empty
        /// unless `degraded`).
        missing_shards: Vec<u64>,
    },
    /// Ranked top-k result, ascending by distance.
    TopK {
        /// Hits, ascending by distance.
        hits: Vec<Hit>,
        /// Present iff the request set its `trace` flag.
        trace: Option<QueryTrace>,
        /// True when the answer covers only part of the database (one
        /// or more shards were unreachable). Always false from a
        /// single-node server.
        degraded: bool,
        /// Shard indices that did not contribute, ascending (empty
        /// unless `degraded`).
        missing_shards: Vec<u64>,
    },
    /// Metrics snapshot.
    Stats(WireStats),
    /// Prometheus text exposition document.
    MetricsText(String),
    /// Job accepted by the job plane.
    JobCreated {
        /// Id for subsequent status/events/cancel/result frames.
        id: u64,
    },
    /// Job status snapshot (the answer to `JobStatus` and `JobCancel`).
    JobStatus(JobSnapshot),
    /// Progress events past the polled cursor.
    JobEvents {
        /// Events with `seq > cursor`, oldest first.
        events: Vec<JobEvent>,
        /// Sequence number of the newest retained event (poll again
        /// from here).
        latest_seq: u64,
    },
    /// Completed job's result payload.
    JobResult(crate::jobs::JobResult),
    /// Shutdown acknowledged; the connection closes after this frame.
    ShutdownAck,
    /// Request failed.
    Error(String),
}

/// On-wire tag of a [`PqQueryMode`].
fn mode_tag(m: PqQueryMode) -> u8 {
    match m {
        PqQueryMode::Symmetric => 0,
        PqQueryMode::Asymmetric => 1,
    }
}

/// [`PqQueryMode`] from its on-wire tag.
fn mode_from(tag: u8) -> Result<PqQueryMode> {
    match tag {
        0 => Ok(PqQueryMode::Symmetric),
        1 => Ok(PqQueryMode::Asymmetric),
        other => bail!("net: unknown query-mode tag {other}"),
    }
}

fn put_opt_i64(w: &mut ByteWriter, v: Option<i64>) {
    match v {
        Some(x) => {
            w.u8(1);
            w.bytes(&x.to_le_bytes());
        }
        None => w.u8(0),
    }
}

fn get_i64(r: &mut ByteReader) -> Result<i64> {
    let v = r.u64()?;
    Ok(i64::from_le_bytes(v.to_le_bytes()))
}

fn get_opt_i64(r: &mut ByteReader) -> Result<Option<i64>> {
    match r.u8()? {
        0 => Ok(None),
        1 => Ok(Some(get_i64(r)?)),
        other => bail!("net: bad option flag {other}"),
    }
}

fn get_bool(r: &mut ByteReader) -> Result<bool> {
    match r.u8()? {
        0 => Ok(false),
        1 => Ok(true),
        other => bail!("net: bad bool flag {other}"),
    }
}

fn put_opt_f64(w: &mut ByteWriter, v: Option<f64>) {
    match v {
        Some(x) => {
            w.u8(1);
            w.f64(x);
        }
        None => w.u8(0),
    }
}

fn get_opt_f64(r: &mut ByteReader) -> Result<Option<f64>> {
    match r.u8()? {
        0 => Ok(None),
        1 => Ok(Some(r.f64()?)),
        other => bail!("net: bad option flag {other}"),
    }
}

fn put_opt_u64(w: &mut ByteWriter, v: Option<u64>) {
    match v {
        Some(x) => {
            w.u8(1);
            w.u64(x);
        }
        None => w.u8(0),
    }
}

fn get_opt_u64(r: &mut ByteReader) -> Result<Option<u64>> {
    match r.u8()? {
        0 => Ok(None),
        1 => Ok(Some(r.u64()?)),
        other => bail!("net: bad option flag {other}"),
    }
}

/// A latency histogram's cumulative bucket counts — fixed-size, no
/// length prefix (see [`N_LATENCY_BUCKETS`]).
fn put_buckets(w: &mut ByteWriter, buckets: &[u64]) {
    debug_assert_eq!(buckets.len(), N_LATENCY_BUCKETS);
    for i in 0..N_LATENCY_BUCKETS {
        w.u64(buckets.get(i).copied().unwrap_or(0));
    }
}

fn get_buckets(r: &mut ByteReader) -> Result<Vec<u64>> {
    let mut buckets = Vec::with_capacity(12); // N_LATENCY_BUCKETS, fixed
    for _ in 0..N_LATENCY_BUCKETS {
        buckets.push(r.u64()?);
    }
    Ok(buckets)
}

fn put_trace(w: &mut ByteWriter, t: &QueryTrace) {
    w.u64(t.request_id);
    w.usize(t.spans.len());
    for s in &t.spans {
        w.u8(s.stage.as_u8());
        w.u64(s.wall_us);
        w.u64(s.candidates_in);
        w.u64(s.candidates_out);
    }
    w.usize(t.hits.len());
    for h in &t.hits {
        w.u64(h.index);
        w.f64(h.pq_estimate);
        put_opt_f64(w, h.exact_dtw);
        w.u8(h.admitted_by.as_u8());
        put_opt_u64(w, h.shard);
    }
    w.u64(t.scan.items_scanned);
    w.u64(t.scan.items_abandoned);
    w.u64(t.scan.blocks_skipped);
    w.u64(t.scan.lut_collapses);
    w.u64(t.scan.shard_time_us);
    w.u64(t.scan.shards);
    w.usize(t.children.len());
    for c in &t.children {
        w.u64(c.shard);
        w.u8(u8::from(c.retried));
        w.u8(u8::from(c.hedged));
        w.u8(u8::from(c.degraded));
        put_trace(w, &c.trace);
    }
}

fn get_stage(r: &mut ByteReader) -> Result<Stage> {
    let v = r.u8()?;
    Stage::from_u8(v).ok_or_else(|| anyhow::anyhow!("net: unknown stage tag {v}"))
}

fn get_trace(r: &mut ByteReader) -> Result<QueryTrace> {
    get_trace_at_depth(r, 0)
}

/// Decode one trace body. `depth` is 0 for a top-level trace and 1 for
/// a per-shard child; children below a child are rejected so a hostile
/// frame cannot recurse the decoder.
fn get_trace_at_depth(r: &mut ByteReader, depth: usize) -> Result<QueryTrace> {
    let request_id = r.u64()?;
    let n_spans = r.usize()?;
    // stage tag + wall + in + out = 25 B per span; reject counts the
    // frame cannot back before reserving capacity.
    ensure!(
        n_spans.saturating_mul(25) <= r.remaining(),
        "net: span count {n_spans} exceeds remaining frame bytes"
    );
    let mut spans = Vec::with_capacity(n_spans);
    for _ in 0..n_spans {
        spans.push(StageSpan {
            stage: get_stage(r)?,
            wall_us: r.u64()?,
            candidates_in: r.u64()?,
            candidates_out: r.u64()?,
        });
    }
    let n_hits = r.usize()?;
    // index + estimate + exact presence byte + stage tag + shard
    // presence byte = ≥ 19 B.
    ensure!(
        n_hits.saturating_mul(19) <= r.remaining(),
        "net: explain count {n_hits} exceeds remaining frame bytes"
    );
    let mut hits = Vec::with_capacity(n_hits);
    for _ in 0..n_hits {
        hits.push(HitExplain {
            index: r.u64()?,
            pq_estimate: r.f64()?,
            exact_dtw: get_opt_f64(r)?,
            admitted_by: get_stage(r)?,
            shard: get_opt_u64(r)?,
        });
    }
    let scan = ScanSnapshot {
        items_scanned: r.u64()?,
        items_abandoned: r.u64()?,
        blocks_skipped: r.u64()?,
        lut_collapses: r.u64()?,
        shard_time_us: r.u64()?,
        shards: r.u64()?,
    };
    let n_children = r.usize()?;
    ensure!(
        depth == 0 || n_children == 0,
        "net: child traces may not carry children (depth limit 1)"
    );
    // shard id + three flag bytes + the minimal empty trace body
    // (request id + three zero counts + scan snapshot = 80 B) = 91 B.
    ensure!(
        n_children.saturating_mul(91) <= r.remaining(),
        "net: child-trace count {n_children} exceeds remaining frame bytes"
    );
    let mut children = Vec::with_capacity(n_children);
    for _ in 0..n_children {
        children.push(ChildTrace {
            shard: r.u64()?,
            retried: get_bool(r)?,
            hedged: get_bool(r)?,
            degraded: get_bool(r)?,
            trace: get_trace_at_depth(r, depth + 1)?,
        });
    }
    ensure!(
        children.windows(2).all(|c| c[0].shard < c[1].shard),
        "net: child-trace shard ids must be strictly ascending"
    );
    Ok(QueryTrace { request_id, spans, hits, scan, children })
}

fn put_opt_trace(w: &mut ByteWriter, t: &Option<QueryTrace>) {
    match t {
        Some(t) => {
            w.u8(1);
            put_trace(w, t);
        }
        None => w.u8(0),
    }
}

fn get_opt_trace(r: &mut ByteReader) -> Result<Option<QueryTrace>> {
    match r.u8()? {
        0 => Ok(None),
        1 => Ok(Some(get_trace(r)?)),
        other => bail!("net: bad option flag {other}"),
    }
}

/// The v4 degraded-mode trailer on query results: flag + missing-shard
/// list (ascending, empty unless degraded).
fn put_degraded(w: &mut ByteWriter, degraded: bool, missing_shards: &[u64]) {
    w.u8(u8::from(degraded));
    w.usize(missing_shards.len());
    for &s in missing_shards {
        w.u64(s);
    }
}

fn get_degraded(r: &mut ByteReader) -> Result<(bool, Vec<u64>)> {
    let degraded = get_bool(r)?;
    let n = r.usize()?;
    ensure!(
        n.saturating_mul(8) <= r.remaining(),
        "net: missing-shard count {n} exceeds remaining frame bytes"
    );
    let mut missing = Vec::with_capacity(n);
    for _ in 0..n {
        missing.push(r.u64()?);
    }
    ensure!(
        missing.windows(2).all(|w| w[0] < w[1]),
        "net: missing-shard list must be strictly ascending"
    );
    ensure!(
        degraded || missing.is_empty(),
        "net: missing shards listed on a non-degraded result"
    );
    Ok((degraded, missing))
}

/// Frame a payload: header (magic, version, tag, length) + payload.
pub fn encode_frame(tag: u8, payload: &[u8]) -> Vec<u8> {
    let mut w = ByteWriter::new();
    w.bytes(&NET_MAGIC);
    w.u32(NET_VERSION);
    w.u8(tag);
    w.usize(payload.len());
    w.bytes(payload);
    w.into_bytes()
}

/// Serialize a request into one wire frame.
pub fn encode_request(req: &NetRequest) -> Vec<u8> {
    let mut p = ByteWriter::new();
    let tag = match req {
        NetRequest::Ping => TAG_PING,
        NetRequest::Nn { series, mode, nprobe, request_id, trace } => {
            p.u64(*request_id);
            p.u8(u8::from(*trace));
            p.u8(mode_tag(*mode));
            p.opt_usize(*nprobe);
            p.vec_f64(series);
            TAG_NN
        }
        NetRequest::TopK { series, k, mode, nprobe, rerank, request_id, trace } => {
            p.u64(*request_id);
            p.u8(u8::from(*trace));
            p.usize(*k);
            p.u8(mode_tag(*mode));
            p.opt_usize(*nprobe);
            p.opt_usize(*rerank);
            p.vec_f64(series);
            TAG_TOPK
        }
        NetRequest::Stats => TAG_STATS,
        NetRequest::MetricsText => TAG_METRICS_TEXT,
        NetRequest::JobCreate { spec } => {
            jobs_codec::put_spec(&mut p, spec);
            TAG_JOB_CREATE
        }
        NetRequest::JobStatus { id } => {
            p.u64(*id);
            TAG_JOB_STATUS
        }
        NetRequest::JobEvents { id, cursor, max } => {
            p.u64(*id);
            p.u64(*cursor);
            p.usize(*max);
            TAG_JOB_EVENTS
        }
        NetRequest::JobCancel { id } => {
            p.u64(*id);
            TAG_JOB_CANCEL
        }
        NetRequest::JobResult { id } => {
            p.u64(*id);
            TAG_JOB_RESULT
        }
        NetRequest::Shutdown => TAG_SHUTDOWN,
    };
    encode_frame(tag, &p.into_bytes())
}

/// Query series with the semantic length limit applied (the byte-level
/// count-vs-remaining check lives in `ByteReader::vec_f64`).
fn get_query_series(r: &mut ByteReader) -> Result<Vec<f64>> {
    let series = r.vec_f64()?;
    ensure!(
        series.len() <= MAX_QUERY_LEN,
        "net: query of {} samples exceeds the {MAX_QUERY_LEN}-sample limit",
        series.len()
    );
    ensure!(!series.is_empty(), "net: empty query series");
    Ok(series)
}

/// Deserialize and validate a request payload.
pub fn decode_request(tag: u8, payload: &[u8]) -> Result<NetRequest> {
    let mut r = ByteReader::new(payload);
    let req = match tag {
        TAG_PING => NetRequest::Ping,
        TAG_NN => {
            let request_id = r.u64()?;
            let trace = get_bool(&mut r)?;
            let mode = mode_from(r.u8()?)?;
            let nprobe = r.opt_usize()?;
            let series = get_query_series(&mut r)?;
            NetRequest::Nn { series, mode, nprobe, request_id, trace }
        }
        TAG_TOPK => {
            let request_id = r.u64()?;
            let trace = get_bool(&mut r)?;
            let k = r.usize()?;
            ensure!(k >= 1, "net: k must be >= 1");
            let mode = mode_from(r.u8()?)?;
            let nprobe = r.opt_usize()?;
            let rerank = r.opt_usize()?;
            let series = get_query_series(&mut r)?;
            NetRequest::TopK { series, k, mode, nprobe, rerank, request_id, trace }
        }
        TAG_STATS => NetRequest::Stats,
        TAG_METRICS_TEXT => NetRequest::MetricsText,
        TAG_JOB_CREATE => NetRequest::JobCreate { spec: jobs_codec::get_spec(&mut r)? },
        TAG_JOB_STATUS => NetRequest::JobStatus { id: r.u64()? },
        TAG_JOB_EVENTS => {
            let id = r.u64()?;
            let cursor = r.u64()?;
            let max = r.usize()?;
            ensure!(
                max >= 1 && max <= MAX_JOB_EVENTS,
                "net: job-events max {max} outside 1..={MAX_JOB_EVENTS}"
            );
            NetRequest::JobEvents { id, cursor, max }
        }
        TAG_JOB_CANCEL => NetRequest::JobCancel { id: r.u64()? },
        TAG_JOB_RESULT => NetRequest::JobResult { id: r.u64()? },
        TAG_SHUTDOWN => NetRequest::Shutdown,
        other => bail!("net: unknown request tag {other}"),
    };
    ensure!(r.is_exhausted(), "net: trailing bytes in request payload");
    Ok(req)
}

fn put_stats(w: &mut ByteWriter, s: &WireStats) {
    w.u64(s.requests);
    w.u64(s.errors);
    w.u64(s.batches);
    w.f64(s.mean_batch_size);
    w.f64(s.mean_latency_us);
    w.u64(s.p50_us);
    w.u64(s.p99_us);
    put_buckets(w, &s.latency_buckets);
    w.usize(s.per_class.len());
    for c in &s.per_class {
        w.u8(c.class);
        w.string(&c.name);
        w.u64(c.requests);
        w.f64(c.mean_latency_us);
        w.u64(c.p50_us);
        w.u64(c.p99_us);
        put_buckets(w, &c.buckets);
    }
    w.usize(s.per_stage.len());
    for st in &s.per_stage {
        w.u8(st.stage);
        w.string(&st.name);
        w.u64(st.count);
        w.f64(st.mean_us);
        w.u64(st.p50_us);
        w.u64(st.p99_us);
        put_buckets(w, &st.buckets);
    }
    w.u64(s.scan.items_scanned);
    w.u64(s.scan.items_abandoned);
    w.u64(s.scan.blocks_skipped);
    w.u64(s.scan.lut_collapses);
    w.u64(s.scan.shard_time_us);
    w.u64(s.scan.shards);
    w.u64(s.uptime_s);
    w.string(&s.version);
    w.u64(s.n_items);
    w.u64(s.n_subspaces);
    w.u64(s.codebook_size);
    w.u64(s.series_len);
    w.f64(s.window_frac);
    w.string(&s.coarse_metric);
    w.opt_usize(s.nlist);
}

fn get_stats(r: &mut ByteReader) -> Result<WireStats> {
    let requests = r.u64()?;
    let errors = r.u64()?;
    let batches = r.u64()?;
    let mean_batch_size = r.f64()?;
    let mean_latency_us = r.f64()?;
    let p50_us = r.u64()?;
    let p99_us = r.u64()?;
    let latency_buckets = get_buckets(r)?;
    let n = r.usize()?;
    // Each class entry holds at least tag + name length + counters +
    // the fixed 96-byte bucket array, so any count claiming more than
    // the remaining bytes could encode is hostile — reject before
    // reserving capacity.
    ensure!(
        n.saturating_mul(137) <= r.remaining(),
        "net: stats class count {n} exceeds remaining frame bytes"
    );
    let mut per_class = Vec::with_capacity(n);
    for _ in 0..n {
        per_class.push(WireClassStats {
            class: r.u8()?,
            name: r.string()?,
            requests: r.u64()?,
            mean_latency_us: r.f64()?,
            p50_us: r.u64()?,
            p99_us: r.u64()?,
            buckets: get_buckets(r)?,
        });
    }
    let n_stages = r.usize()?;
    // Same minimum entry size as a class: tag + name length prefix +
    // four 8-byte counters + the fixed bucket array.
    ensure!(
        n_stages.saturating_mul(137) <= r.remaining(),
        "net: stats stage count {n_stages} exceeds remaining frame bytes"
    );
    let mut per_stage = Vec::with_capacity(n_stages);
    for _ in 0..n_stages {
        per_stage.push(WireStageStats {
            stage: r.u8()?,
            name: r.string()?,
            count: r.u64()?,
            mean_us: r.f64()?,
            p50_us: r.u64()?,
            p99_us: r.u64()?,
            buckets: get_buckets(r)?,
        });
    }
    let scan = ScanSnapshot {
        items_scanned: r.u64()?,
        items_abandoned: r.u64()?,
        blocks_skipped: r.u64()?,
        lut_collapses: r.u64()?,
        shard_time_us: r.u64()?,
        shards: r.u64()?,
    };
    let uptime_s = r.u64()?;
    let version = r.string()?;
    let n_items = r.u64()?;
    let n_subspaces = r.u64()?;
    let codebook_size = r.u64()?;
    let series_len = r.u64()?;
    let window_frac = r.f64()?;
    let coarse_metric = r.string()?;
    let nlist = r.opt_usize()?;
    Ok(WireStats {
        requests,
        errors,
        batches,
        mean_batch_size,
        mean_latency_us,
        p50_us,
        p99_us,
        latency_buckets,
        per_class,
        per_stage,
        scan,
        uptime_s,
        version,
        n_items,
        n_subspaces,
        codebook_size,
        series_len,
        window_frac,
        coarse_metric,
        nlist,
    })
}

/// Serialize a response into one wire frame.
pub fn encode_response(resp: &NetResponse) -> Vec<u8> {
    let mut p = ByteWriter::new();
    let tag = match resp {
        NetResponse::Pong => TAG_PONG,
        NetResponse::Nn { index, distance, label, trace, degraded, missing_shards } => {
            p.usize(*index);
            p.f64(*distance);
            put_opt_i64(&mut p, *label);
            put_opt_trace(&mut p, trace);
            put_degraded(&mut p, *degraded, missing_shards);
            TAG_NN_RESULT
        }
        NetResponse::TopK { hits, trace, degraded, missing_shards } => {
            p.usize(hits.len());
            for h in hits {
                p.usize(h.index);
                p.f64(h.distance);
                put_opt_i64(&mut p, h.label);
            }
            put_opt_trace(&mut p, trace);
            put_degraded(&mut p, *degraded, missing_shards);
            TAG_TOPK_RESULT
        }
        NetResponse::Stats(s) => {
            put_stats(&mut p, s);
            TAG_STATS_RESULT
        }
        NetResponse::MetricsText(text) => {
            p.string(text);
            TAG_METRICS_TEXT_RESULT
        }
        NetResponse::JobCreated { id } => {
            p.u64(*id);
            TAG_JOB_CREATED
        }
        NetResponse::JobStatus(snap) => {
            jobs_codec::put_snapshot(&mut p, snap);
            TAG_JOB_STATUS_RESULT
        }
        NetResponse::JobEvents { events, latest_seq } => {
            jobs_codec::put_events(&mut p, events);
            p.u64(*latest_seq);
            TAG_JOB_EVENTS_RESULT
        }
        NetResponse::JobResult(result) => {
            jobs_codec::put_result(&mut p, result);
            TAG_JOB_RESULT_RESULT
        }
        NetResponse::ShutdownAck => TAG_SHUTDOWN_ACK,
        NetResponse::Error(msg) => {
            p.string(msg);
            TAG_ERROR
        }
    };
    encode_frame(tag, &p.into_bytes())
}

/// Deserialize and validate a response payload.
pub fn decode_response(tag: u8, payload: &[u8]) -> Result<NetResponse> {
    let mut r = ByteReader::new(payload);
    let resp = match tag {
        TAG_PONG => NetResponse::Pong,
        TAG_NN_RESULT => {
            let index = r.usize()?;
            let distance = r.f64()?;
            let label = get_opt_i64(&mut r)?;
            let trace = get_opt_trace(&mut r)?;
            let (degraded, missing_shards) = get_degraded(&mut r)?;
            NetResponse::Nn { index, distance, label, trace, degraded, missing_shards }
        }
        TAG_TOPK_RESULT => {
            let n = r.usize()?;
            // index + distance + label presence byte = ≥ 17 B per hit
            ensure!(
                n.saturating_mul(17) <= r.remaining(),
                "net: hit count {n} exceeds remaining frame bytes"
            );
            let mut hits = Vec::with_capacity(n);
            for _ in 0..n {
                let index = r.usize()?;
                let distance = r.f64()?;
                let label = get_opt_i64(&mut r)?;
                hits.push(Hit { index, distance, label });
            }
            let trace = get_opt_trace(&mut r)?;
            let (degraded, missing_shards) = get_degraded(&mut r)?;
            NetResponse::TopK { hits, trace, degraded, missing_shards }
        }
        TAG_STATS_RESULT => NetResponse::Stats(get_stats(&mut r)?),
        TAG_METRICS_TEXT_RESULT => NetResponse::MetricsText(r.string()?),
        TAG_JOB_CREATED => NetResponse::JobCreated { id: r.u64()? },
        TAG_JOB_STATUS_RESULT => NetResponse::JobStatus(jobs_codec::get_snapshot(&mut r)?),
        TAG_JOB_EVENTS_RESULT => {
            let events = jobs_codec::get_events(&mut r)?;
            let latest_seq = r.u64()?;
            NetResponse::JobEvents { events, latest_seq }
        }
        TAG_JOB_RESULT_RESULT => NetResponse::JobResult(jobs_codec::get_result(&mut r)?),
        TAG_SHUTDOWN_ACK => NetResponse::ShutdownAck,
        TAG_ERROR => NetResponse::Error(r.string()?),
        other => bail!("net: unknown response tag {other}"),
    };
    ensure!(r.is_exhausted(), "net: trailing bytes in response payload");
    Ok(resp)
}

/// Read one frame from a stream. `Ok(None)` means a clean EOF at a
/// frame boundary (the peer closed between frames). A malformed header
/// or an over-limit length is an `Err`; the stream can no longer be
/// assumed frame-synchronized and the caller should drop it.
pub fn read_frame(r: &mut impl Read, max_frame_bytes: usize) -> Result<Option<(u8, Vec<u8>)>> {
    let mut header = [0u8; HEADER_BYTES];
    // Read the first byte separately so EOF at a frame boundary is
    // distinguishable from a frame torn mid-header.
    let n = loop {
        match r.read(&mut header[..1]) {
            Ok(n) => break n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e).context("net: reading frame header"),
        }
    };
    if n == 0 {
        return Ok(None);
    }
    r.read_exact(&mut header[1..]).context("net: truncated frame header")?;
    // The header buffer always holds HEADER_BYTES, so these reads
    // cannot fail — but they propagate rather than panic regardless.
    let mut h = ByteReader::new(&header);
    let magic = h.take(8)?;
    ensure!(
        magic == &NET_MAGIC[..],
        "net: bad frame magic {magic:02x?} (not a pqdtw peer?)"
    );
    let version = h.u32()?;
    ensure!(
        version == NET_VERSION,
        "net: unsupported protocol version {version} (this build speaks {NET_VERSION})"
    );
    let tag = h.u8()?;
    let len = h.u64()?;
    ensure!(
        len <= max_frame_bytes as u64,
        "net: frame of {len} bytes exceeds the {max_frame_bytes}-byte limit"
    );
    let len = usize::try_from(len).context("net: frame length exceeds usize")?;
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload).context("net: truncated frame payload")?;
    Ok(Some((tag, payload)))
}

/// Write one pre-encoded frame and flush it.
pub fn write_frame(w: &mut impl Write, frame: &[u8]) -> std::io::Result<()> {
    w.write_all(frame)?;
    w.flush()
}

/// Decode a request from a complete, exact frame byte buffer (the
/// hostile-frame sweep drives this; live connections use
/// [`read_frame`] + [`decode_request`]).
pub fn decode_request_bytes(bytes: &[u8]) -> Result<NetRequest> {
    let mut cursor = std::io::Cursor::new(bytes);
    match read_frame(&mut cursor, MAX_FRAME_BYTES)? {
        None => bail!("net: empty frame buffer"),
        Some((tag, payload)) => {
            ensure!(
                cursor.position() == bytes.len() as u64,
                "net: trailing bytes after frame"
            );
            decode_request(tag, &payload)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_trace() -> QueryTrace {
        QueryTrace {
            request_id: 77,
            spans: vec![
                StageSpan {
                    stage: Stage::LutCollapse,
                    wall_us: 2,
                    candidates_in: 128,
                    candidates_out: 128,
                },
                StageSpan {
                    stage: Stage::BlockedScan,
                    wall_us: 41,
                    candidates_in: 128,
                    candidates_out: 9,
                },
            ],
            hits: vec![
                HitExplain {
                    index: 3,
                    pq_estimate: 0.5,
                    exact_dtw: Some(0.625),
                    admitted_by: Stage::Rerank,
                    shard: None,
                },
                HitExplain {
                    index: 11,
                    pq_estimate: 0.75,
                    exact_dtw: None,
                    admitted_by: Stage::BlockedScan,
                    shard: None,
                },
            ],
            scan: ScanSnapshot {
                items_scanned: 128,
                items_abandoned: 119,
                blocks_skipped: 1,
                lut_collapses: 1,
                shard_time_us: 40,
                shards: 1,
            },
            children: Vec::new(),
        }
    }

    /// A router-merged trace: fanout/shard_rpc/merge ladder, per-hit
    /// shard provenance, and per-shard child traces.
    fn sample_routed_trace() -> QueryTrace {
        QueryTrace {
            request_id: 901,
            spans: vec![
                StageSpan {
                    stage: Stage::Fanout,
                    wall_us: 3,
                    candidates_in: 2,
                    candidates_out: 2,
                },
                StageSpan {
                    stage: Stage::ShardRpc,
                    wall_us: 120,
                    candidates_in: 1,
                    candidates_out: 1,
                },
                StageSpan {
                    stage: Stage::ShardRpc,
                    wall_us: 95,
                    candidates_in: 1,
                    candidates_out: 1,
                },
                StageSpan {
                    stage: Stage::Merge,
                    wall_us: 2,
                    candidates_in: 4,
                    candidates_out: 2,
                },
            ],
            hits: vec![
                HitExplain {
                    index: 3,
                    pq_estimate: 0.5,
                    exact_dtw: Some(0.625),
                    admitted_by: Stage::Rerank,
                    shard: Some(0),
                },
                HitExplain {
                    index: 11,
                    pq_estimate: 0.75,
                    exact_dtw: None,
                    admitted_by: Stage::BlockedScan,
                    shard: Some(2),
                },
            ],
            scan: ScanSnapshot {
                items_scanned: 256,
                items_abandoned: 238,
                blocks_skipped: 2,
                lut_collapses: 2,
                shard_time_us: 80,
                shards: 2,
            },
            children: vec![
                ChildTrace {
                    shard: 0,
                    retried: false,
                    hedged: false,
                    degraded: false,
                    trace: sample_trace(),
                },
                ChildTrace {
                    shard: 2,
                    retried: true,
                    hedged: true,
                    degraded: true,
                    trace: QueryTrace::default(),
                },
            ],
        }
    }

    fn sample_requests() -> Vec<NetRequest> {
        vec![
            NetRequest::Ping,
            NetRequest::Stats,
            NetRequest::MetricsText,
            NetRequest::Shutdown,
            NetRequest::Nn {
                series: vec![0.25, -1.5, f64::NAN, 3.0],
                mode: PqQueryMode::Symmetric,
                nprobe: Some(4),
                request_id: 0,
                trace: false,
            },
            NetRequest::TopK {
                series: vec![1.0; 16],
                k: 5,
                mode: PqQueryMode::Asymmetric,
                nprobe: None,
                rerank: Some(20),
                request_id: u64::MAX,
                trace: true,
            },
            NetRequest::JobCreate {
                spec: JobSpec::AllPairsTopK {
                    k: 3,
                    mode: PqQueryMode::Asymmetric,
                    nprobe: Some(2),
                    rerank: Some(16),
                },
            },
            NetRequest::JobCreate {
                spec: JobSpec::ClusterSweep { k_clusters: 4, max_iters: 10, seed: 99 },
            },
            NetRequest::JobCreate {
                spec: JobSpec::AutotuneNprobe { k: 5, target_recall: 0.95, sample: 32 },
            },
            NetRequest::JobStatus { id: 3 },
            NetRequest::JobEvents { id: 3, cursor: 17, max: 64 },
            NetRequest::JobCancel { id: u64::MAX },
            NetRequest::JobResult { id: 1 },
        ]
    }

    fn sample_responses() -> Vec<NetResponse> {
        use crate::jobs::{AllPairsRow, JobKind, JobStatus, SweepPoint};
        vec![
            NetResponse::Pong,
            NetResponse::ShutdownAck,
            NetResponse::Error("nope".into()),
            NetResponse::JobCreated { id: 7 },
            NetResponse::JobStatus(JobSnapshot {
                id: 7,
                kind: JobKind::AllPairsTopK,
                status: JobStatus::Running,
                done: 12,
                total: 64,
                eta_us: Some(1_500_000),
                latest_seq: 4,
            }),
            NetResponse::JobStatus(JobSnapshot {
                id: 2,
                kind: JobKind::ClusterSweep,
                status: JobStatus::Failed("worker died".into()),
                done: 3,
                total: 10,
                eta_us: None,
                latest_seq: 9,
            }),
            NetResponse::JobEvents {
                events: vec![JobEvent {
                    seq: 5,
                    stage: Stage::BlockedScan,
                    done: 16,
                    total: 64,
                    eta_us: Some(200),
                    message: "scanned queries 0..16".into(),
                }],
                latest_seq: 5,
            },
            NetResponse::JobEvents { events: vec![], latest_seq: 0 },
            NetResponse::JobResult(crate::jobs::JobResult::AllPairs(vec![AllPairsRow {
                query_index: 1,
                hits: vec![Hit { index: 1, distance: 0.0, label: Some(4) }],
                explains: vec![HitExplain {
                    index: 1,
                    pq_estimate: 0.0,
                    exact_dtw: Some(0.0),
                    admitted_by: Stage::Rerank,
                    shard: None,
                }],
            }])),
            NetResponse::JobResult(crate::jobs::JobResult::Autotune {
                recommended_nprobe: 4,
                sweep: vec![
                    SweepPoint { nprobe: 1, recall: 0.5 },
                    SweepPoint { nprobe: 4, recall: 1.0 },
                ],
            }),
            NetResponse::JobResult(crate::jobs::JobResult::Cluster {
                medoids: vec![4, 1],
                assignment: vec![0, 1, 0],
                cost: 2.5,
            }),
            NetResponse::MetricsText(
                "# TYPE pqdtw_requests_total counter\npqdtw_requests_total 3\n".into(),
            ),
            NetResponse::Nn {
                index: 7,
                distance: 1.25,
                label: Some(-3),
                trace: None,
                degraded: false,
                missing_shards: vec![],
            },
            NetResponse::Nn {
                index: 2,
                distance: 0.5,
                label: None,
                trace: Some(sample_trace()),
                degraded: true,
                missing_shards: vec![1],
            },
            NetResponse::TopK {
                hits: vec![
                    Hit { index: 0, distance: 0.5, label: None },
                    Hit { index: 9, distance: 0.75, label: Some(2) },
                ],
                trace: None,
                degraded: true,
                missing_shards: vec![0, 2],
            },
            NetResponse::TopK {
                hits: vec![Hit { index: 3, distance: 0.625, label: None }],
                trace: Some(sample_trace()),
                degraded: false,
                missing_shards: vec![],
            },
            NetResponse::TopK {
                hits: vec![
                    Hit { index: 3, distance: 0.625, label: None },
                    Hit { index: 11, distance: 0.75, label: Some(1) },
                ],
                trace: Some(sample_routed_trace()),
                degraded: true,
                missing_shards: vec![1],
            },
            NetResponse::Nn {
                index: 3,
                distance: 0.625,
                label: None,
                trace: Some(sample_routed_trace()),
                degraded: false,
                missing_shards: vec![],
            },
            NetResponse::Stats(WireStats {
                requests: 10,
                errors: 1,
                batches: 4,
                mean_batch_size: 2.5,
                mean_latency_us: 120.0,
                p50_us: 100,
                p99_us: 1000,
                latency_buckets: vec![0, 1, 2, 4, 8, 9, 10, 10, 10, 10, 10, 10],
                per_class: vec![WireClassStats {
                    class: 3,
                    name: "topk_exhaustive".into(),
                    requests: 10,
                    mean_latency_us: 120.0,
                    p50_us: 100,
                    p99_us: 1000,
                    buckets: vec![0, 1, 2, 4, 8, 9, 10, 10, 10, 10, 10, 10],
                }],
                per_stage: vec![WireStageStats {
                    stage: 2,
                    name: "blocked_scan".into(),
                    count: 10,
                    mean_us: 40.5,
                    p50_us: 50,
                    p99_us: 100,
                    buckets: vec![0, 2, 5, 10, 10, 10, 10, 10, 10, 10, 10, 10],
                }],
                scan: ScanSnapshot {
                    items_scanned: 1280,
                    items_abandoned: 1100,
                    blocks_skipped: 4,
                    lut_collapses: 10,
                    shard_time_us: 400,
                    shards: 10,
                },
                uptime_s: 61,
                version: "0.1.0".into(),
                n_items: 128,
                n_subspaces: 4,
                codebook_size: 8,
                series_len: 64,
                window_frac: 0.1,
                coarse_metric: "dtw".into(),
                nlist: Some(16),
            }),
        ]
    }

    fn roundtrip_request(req: &NetRequest) -> NetRequest {
        decode_request_bytes(&encode_request(req)).unwrap()
    }

    #[test]
    fn request_roundtrip_is_exact() {
        for req in sample_requests() {
            let back = roundtrip_request(&req);
            // NaN breaks PartialEq; compare the NaN-carrying request by
            // bit pattern instead.
            if let (
                NetRequest::Nn { series: a, .. },
                NetRequest::Nn { series: b, .. },
            ) = (&req, &back)
            {
                let a: Vec<u64> = a.iter().map(|x| x.to_bits()).collect();
                let b: Vec<u64> = b.iter().map(|x| x.to_bits()).collect();
                assert_eq!(a, b);
            } else {
                assert_eq!(req, back);
            }
        }
    }

    #[test]
    fn response_roundtrip_is_exact() {
        for resp in sample_responses() {
            let frame = encode_response(&resp);
            let mut cursor = std::io::Cursor::new(&frame[..]);
            let (tag, payload) = read_frame(&mut cursor, MAX_FRAME_BYTES).unwrap().unwrap();
            assert_eq!(decode_response(tag, &payload).unwrap(), resp);
        }
    }

    #[test]
    fn clean_eof_is_none_and_torn_header_is_err() {
        let mut empty: &[u8] = &[];
        assert!(read_frame(&mut empty, MAX_FRAME_BYTES).unwrap().is_none());
        let frame = encode_request(&NetRequest::Ping);
        let mut torn = &frame[..HEADER_BYTES - 3];
        assert!(read_frame(&mut torn, MAX_FRAME_BYTES).is_err());
    }

    #[test]
    fn bad_magic_version_tag_and_length_are_rejected() {
        let good = encode_request(&NetRequest::Ping);

        let mut bad_magic = good.clone();
        bad_magic[0] ^= 0xff;
        assert!(decode_request_bytes(&bad_magic).is_err());

        let mut bad_version = good.clone();
        bad_version[8..12].copy_from_slice(&999u32.to_le_bytes());
        let err = decode_request_bytes(&bad_version).unwrap_err().to_string();
        assert!(err.contains("version 999"), "{err}");

        let mut bad_tag = good.clone();
        bad_tag[12] = 200;
        assert!(decode_request_bytes(&bad_tag).is_err());

        // A u64::MAX length claim must be rejected by the frame-size
        // limit before any allocation happens.
        let mut huge_len = good;
        huge_len[13..21].copy_from_slice(&u64::MAX.to_le_bytes());
        let err = decode_request_bytes(&huge_len).unwrap_err().to_string();
        assert!(err.contains("exceeds"), "{err}");
    }

    #[test]
    fn over_limit_query_length_is_rejected() {
        // Forge a TopK payload claiming MAX_QUERY_LEN + 1 samples. The
        // byte-level count check fires first (the frame cannot back the
        // claim), which is exactly the no-unbounded-allocation property.
        let mut p = ByteWriter::new();
        p.u64(0); // request id
        p.u8(0); // trace: off
        p.usize(3); // k
        p.u8(1); // asymmetric
        p.u8(0); // nprobe: None
        p.u8(0); // rerank: None
        p.usize(MAX_QUERY_LEN + 1); // series length prefix, no data
        let frame = encode_frame(TAG_TOPK, &p.into_bytes());
        assert!(decode_request_bytes(&frame).is_err());
    }

    #[test]
    fn empty_query_and_zero_k_are_rejected() {
        let mut p = ByteWriter::new();
        p.u64(0); // request id
        p.u8(0); // trace: off
        p.u8(0); // symmetric
        p.u8(0); // nprobe: None
        p.usize(0); // empty series
        let frame = encode_frame(TAG_NN, &p.into_bytes());
        assert!(decode_request_bytes(&frame).is_err());

        let mut p = ByteWriter::new();
        p.u64(0); // request id
        p.u8(0); // trace: off
        p.usize(0); // k = 0
        p.u8(0);
        p.u8(0);
        p.u8(0);
        p.usize(0);
        let frame = encode_frame(TAG_TOPK, &p.into_bytes());
        assert!(decode_request_bytes(&frame).is_err());
    }

    #[test]
    fn trailing_bytes_are_rejected() {
        let mut frame = encode_request(&NetRequest::Ping);
        frame.push(0);
        assert!(decode_request_bytes(&frame).is_err());
    }

    /// Under Miri each decode is orders of magnitude slower; stride the
    /// exhaustive hostile sweeps so the UB check still samples every
    /// region in reasonable time. Native runs stay exhaustive.
    fn sweep_stride() -> usize {
        if cfg!(miri) {
            13 // prime relative to the 21-byte header and 8-byte fields
        } else {
            1
        }
    }

    #[test]
    fn hostile_sweep_never_panics_or_overallocates() {
        // Every prefix truncation and every single-byte flip of a valid
        // request frame must decode to Err or to some in-limit request —
        // never panic, never allocate beyond the frame limit. (A payload
        // flip can legitimately decode to a *different* valid request;
        // TCP checksums own in-transit integrity.)
        let good = encode_request(&NetRequest::TopK {
            series: vec![0.5; 24],
            k: 3,
            mode: PqQueryMode::Asymmetric,
            nprobe: Some(2),
            rerank: Some(9),
            request_id: 42,
            trace: true,
        });
        for n in (0..good.len()).step_by(sweep_stride()) {
            let _ = decode_request_bytes(&good[..n]);
        }
        for i in (0..good.len()).step_by(sweep_stride()) {
            for bit in [0x01u8, 0x40, 0x80] {
                let mut bad = good.clone();
                bad[i] ^= bit;
                if let Ok(req) = decode_request_bytes(&bad) {
                    match req {
                        NetRequest::Nn { series, .. }
                        | NetRequest::TopK { series, .. } => {
                            assert!(series.len() <= MAX_QUERY_LEN)
                        }
                        NetRequest::JobEvents { max, .. } => {
                            assert!(max <= MAX_JOB_EVENTS)
                        }
                        NetRequest::Ping
                        | NetRequest::Stats
                        | NetRequest::MetricsText
                        | NetRequest::JobCreate { .. }
                        | NetRequest::JobStatus { .. }
                        | NetRequest::JobCancel { .. }
                        | NetRequest::JobResult { .. }
                        | NetRequest::Shutdown => {}
                    }
                }
            }
        }
    }

    #[test]
    fn hostile_job_frames_are_rejected_without_allocating() {
        // A job-events result claiming 2^60 events must be rejected by
        // the count-vs-remaining check before any allocation.
        let mut p = ByteWriter::new();
        p.usize(1 << 60);
        let frame = encode_frame(TAG_JOB_EVENTS_RESULT, &p.into_bytes());
        let mut cursor = std::io::Cursor::new(&frame[..]);
        let (tag, payload) = read_frame(&mut cursor, MAX_FRAME_BYTES).unwrap().unwrap();
        assert!(decode_response(tag, &payload).is_err());

        // An all-pairs result claiming 2^59 rows likewise.
        let mut p = ByteWriter::new();
        p.u8(crate::jobs::JobKind::AllPairsTopK.as_u8());
        p.usize(1 << 59);
        let frame = encode_frame(TAG_JOB_RESULT_RESULT, &p.into_bytes());
        let mut cursor = std::io::Cursor::new(&frame[..]);
        let (tag, payload) = read_frame(&mut cursor, MAX_FRAME_BYTES).unwrap().unwrap();
        assert!(decode_response(tag, &payload).is_err());

        // An events poll with a hostile `max` is rejected at decode.
        let mut p = ByteWriter::new();
        p.u64(1); // id
        p.u64(0); // cursor
        p.usize(MAX_JOB_EVENTS + 1);
        let frame = encode_frame(TAG_JOB_EVENTS, &p.into_bytes());
        let payload = &frame[HEADER_BYTES..];
        assert!(decode_request(TAG_JOB_EVENTS, payload).is_err());

        // Unknown job-kind tag in a create frame.
        let frame = encode_frame(TAG_JOB_CREATE, &[0xEE]);
        let payload = &frame[HEADER_BYTES..];
        assert!(decode_request(TAG_JOB_CREATE, payload).is_err());
    }

    /// The hostile byte-flip/truncation sweep over a *job* frame — the
    /// v3 frames inherit the same no-panic guarantee as the query
    /// frames.
    #[test]
    fn hostile_sweep_over_job_frames_never_panics() {
        let frames = [
            encode_request(&NetRequest::JobCreate {
                spec: JobSpec::AutotuneNprobe { k: 3, target_recall: 0.9, sample: 16 },
            }),
            encode_request(&NetRequest::JobEvents { id: 9, cursor: 4, max: 256 }),
        ];
        for good in frames {
            for n in (0..good.len()).step_by(sweep_stride()) {
                let _ = decode_request_bytes(&good[..n]);
            }
            for i in (0..good.len()).step_by(sweep_stride()) {
                for bit in [0x01u8, 0x40, 0x80] {
                    let mut bad = good.clone();
                    bad[i] ^= bit;
                    if let Ok(NetRequest::JobEvents { max, .. }) =
                        decode_request_bytes(&bad)
                    {
                        assert!(max >= 1 && max <= MAX_JOB_EVENTS);
                    }
                }
            }
        }
    }

    #[test]
    fn response_sweep_never_panics() {
        for resp in sample_responses() {
            let good = encode_response(&resp);
            for n in (0..good.len()).step_by(sweep_stride()) {
                let mut cursor = std::io::Cursor::new(&good[..n]);
                if let Ok(Some((tag, payload))) = read_frame(&mut cursor, MAX_FRAME_BYTES) {
                    let _ = decode_response(tag, &payload);
                }
            }
            for i in (0..good.len()).step_by(sweep_stride()) {
                let mut bad = good.clone();
                bad[i] ^= 0x40;
                let mut cursor = std::io::Cursor::new(&bad[..]);
                if let Ok(Some((tag, payload))) = read_frame(&mut cursor, MAX_FRAME_BYTES) {
                    let _ = decode_response(tag, &payload);
                }
            }
        }
    }

    #[test]
    fn hostile_stats_and_hit_counts_are_rejected_without_allocating() {
        let mut p = ByteWriter::new();
        p.usize(usize::MAX); // hit count
        let frame = encode_frame(TAG_TOPK_RESULT, &p.into_bytes());
        let mut cursor = std::io::Cursor::new(&frame[..]);
        let (tag, payload) = read_frame(&mut cursor, MAX_FRAME_BYTES).unwrap().unwrap();
        assert!(decode_response(tag, &payload).is_err());

        let mut p = ByteWriter::new();
        for _ in 0..7 {
            p.u64(0); // counters through p99
        }
        p.usize(1 << 60); // class count
        let frame = encode_frame(TAG_STATS_RESULT, &p.into_bytes());
        let mut cursor = std::io::Cursor::new(&frame[..]);
        let (tag, payload) = read_frame(&mut cursor, MAX_FRAME_BYTES).unwrap().unwrap();
        assert!(decode_response(tag, &payload).is_err());
    }

    #[test]
    fn hostile_trace_counts_and_stage_tags_are_rejected() {
        // An NN result whose trace claims 2^60 spans must be rejected by
        // the span-count-vs-remaining check before any allocation.
        let mut p = ByteWriter::new();
        p.usize(7); // index
        p.f64(1.0); // distance
        p.u8(0); // label: None
        p.u8(1); // trace present
        p.u64(0); // trace request id
        p.usize(1 << 60); // span count
        let frame = encode_frame(TAG_NN_RESULT, &p.into_bytes());
        let mut cursor = std::io::Cursor::new(&frame[..]);
        let (tag, payload) = read_frame(&mut cursor, MAX_FRAME_BYTES).unwrap().unwrap();
        assert!(decode_response(tag, &payload).is_err());

        // An unknown stage discriminant in a span is hostile input.
        let mut resp = NetResponse::Nn {
            index: 7,
            distance: 1.0,
            label: None,
            trace: Some(sample_trace()),
            degraded: false,
            missing_shards: vec![],
        };
        if let NetResponse::Nn { trace: Some(t), .. } = &mut resp {
            t.hits.clear(); // keep the forged byte offset simple
        }
        let mut frame = encode_response(&resp);
        // Payload starts after the header; the first span's stage tag
        // sits after index (8) + distance (8) + label flag (1) + trace
        // flag (1) + trace request id (8) + span count (8).
        let stage_off = HEADER_BYTES + 8 + 8 + 1 + 1 + 8 + 8;
        assert!(Stage::from_u8(frame[stage_off]).is_some(), "offset arithmetic drifted");
        frame[stage_off] = 250;
        let mut cursor = std::io::Cursor::new(&frame[..]);
        let (tag, payload) = read_frame(&mut cursor, MAX_FRAME_BYTES).unwrap().unwrap();
        let err = decode_response(tag, &payload).unwrap_err().to_string();
        assert!(err.contains("stage tag"), "{err}");
    }

    #[test]
    fn hostile_child_traces_are_rejected() {
        // Build an NN-result payload carrying an empty trace body plus
        // a forged child section, then decode it.
        fn decode_nn_with_children(
            children: impl FnOnce(&mut ByteWriter),
        ) -> Result<NetResponse> {
            let mut p = ByteWriter::new();
            p.usize(7); // index
            p.f64(1.0); // distance
            p.u8(0); // label: None
            p.u8(1); // trace present
            p.u64(0); // trace request id
            p.usize(0); // spans
            p.usize(0); // hits
            for _ in 0..6 {
                p.u64(0); // scan snapshot
            }
            children(&mut p);
            p.u8(0); // not degraded
            p.usize(0); // no missing shards
            let frame = encode_frame(TAG_NN_RESULT, &p.into_bytes());
            let mut cursor = std::io::Cursor::new(&frame[..]);
            let (tag, payload) = read_frame(&mut cursor, MAX_FRAME_BYTES).unwrap().unwrap();
            decode_response(tag, &payload)
        }

        /// One minimal well-formed child body (empty trace).
        fn put_child(p: &mut ByteWriter, shard: u64) {
            p.u64(shard);
            p.u8(0); // retried
            p.u8(0); // hedged
            p.u8(0); // degraded
            p.u64(0); // child request id
            p.usize(0); // spans
            p.usize(0); // hits
            for _ in 0..6 {
                p.u64(0); // scan snapshot
            }
            p.usize(0); // grandchildren
        }

        // A child count the frame cannot back is rejected before any
        // allocation.
        let err = decode_nn_with_children(|p| p.usize(1 << 60)).unwrap_err().to_string();
        assert!(err.contains("child-trace count"), "{err}");

        // Child shard ids must be strictly ascending (canonical form).
        let err = decode_nn_with_children(|p| {
            p.usize(2);
            put_child(p, 3);
            put_child(p, 1);
        })
        .unwrap_err()
        .to_string();
        assert!(err.contains("ascending"), "{err}");

        // A well-formed child section decodes.
        let resp = decode_nn_with_children(|p| {
            p.usize(1);
            put_child(p, 2);
        })
        .unwrap();
        match resp {
            NetResponse::Nn { trace: Some(t), .. } => {
                assert_eq!(t.children.len(), 1);
                assert_eq!(t.children[0].shard, 2);
            }
            other => panic!("unexpected response {other:?}"),
        }

        // A grandchild (depth 2) is rejected even when well-formed —
        // the decoder's recursion is bounded.
        let grandchild = ChildTrace {
            shard: 0,
            retried: false,
            hedged: false,
            degraded: false,
            trace: QueryTrace::default(),
        };
        let child = ChildTrace {
            shard: 0,
            retried: false,
            hedged: false,
            degraded: false,
            trace: QueryTrace { children: vec![grandchild], ..QueryTrace::default() },
        };
        let resp = NetResponse::Nn {
            index: 0,
            distance: 0.0,
            label: None,
            trace: Some(QueryTrace {
                children: vec![child],
                ..QueryTrace::default()
            }),
            degraded: false,
            missing_shards: vec![],
        };
        let frame = encode_response(&resp);
        let mut cursor = std::io::Cursor::new(&frame[..]);
        let (tag, payload) = read_frame(&mut cursor, MAX_FRAME_BYTES).unwrap().unwrap();
        let err = decode_response(tag, &payload).unwrap_err().to_string();
        assert!(err.contains("depth"), "{err}");
    }

    #[test]
    fn hostile_degraded_trailers_are_rejected() {
        fn decode_nn(payload_writer: impl FnOnce(&mut ByteWriter)) -> Result<NetResponse> {
            let mut p = ByteWriter::new();
            p.usize(7); // index
            p.f64(1.0); // distance
            p.u8(0); // label: None
            p.u8(0); // trace: None
            payload_writer(&mut p);
            let frame = encode_frame(TAG_NN_RESULT, &p.into_bytes());
            let mut cursor = std::io::Cursor::new(&frame[..]);
            let (tag, payload) = read_frame(&mut cursor, MAX_FRAME_BYTES).unwrap().unwrap();
            decode_response(tag, &payload)
        }

        // A missing-shard count the frame cannot back is rejected
        // before any allocation.
        let err = decode_nn(|p| {
            p.u8(1); // degraded
            p.usize(1 << 60);
        })
        .unwrap_err()
        .to_string();
        assert!(err.contains("missing-shard count"), "{err}");

        // Shards listed on a non-degraded result are contradictory.
        let err = decode_nn(|p| {
            p.u8(0); // not degraded
            p.usize(1);
            p.u64(2);
        })
        .unwrap_err()
        .to_string();
        assert!(err.contains("non-degraded"), "{err}");

        // The shard list must be strictly ascending (canonical form).
        let err = decode_nn(|p| {
            p.u8(1); // degraded
            p.usize(2);
            p.u64(2);
            p.u64(1);
        })
        .unwrap_err()
        .to_string();
        assert!(err.contains("ascending"), "{err}");

        // A well-formed degraded trailer decodes.
        let resp = decode_nn(|p| {
            p.u8(1); // degraded
            p.usize(1);
            p.u64(2);
        })
        .unwrap();
        match resp {
            NetResponse::Nn { degraded, missing_shards, .. } => {
                assert!(degraded);
                assert_eq!(missing_shards, vec![2]);
            }
            other => panic!("unexpected response {other:?}"),
        }
    }
}
