//! `net` — the network serving plane: remote PQDTW queries over a
//! versioned binary wire protocol, std-only (`std::net` + threads; no
//! external runtime — see `docs/DESIGN.md` §3).
//!
//! Until this subsystem existed every query had to run inside the
//! `pqdtw` process: `serve` drove a synthetic in-process loop, so the
//! batcher, IVF probing and the on-disk index store were unreachable
//! from any other program. The net plane turns the reproduction into a
//! service: a long-lived server amortizes one index load across many
//! clients, and concurrent connections feed the same
//! [`DynamicBatcher`](crate::coordinator::DynamicBatcher), so
//! cross-connection batching happens for free.
//!
//! - [`protocol`] — length-prefixed little-endian frames (magic,
//!   version, tag, payload) reusing the store's codec primitives and
//!   its hardening discipline; hostile frames yield error responses or
//!   clean disconnects, never panics or unbounded allocations. Byte
//!   layout and version-bump policy: `docs/wire-protocol.md`.
//! - [`server`] — `TcpListener` accept loop, per-connection
//!   reader/writer threads over the shared
//!   [`Service`](crate::coordinator::Service), connection cap, bounded
//!   per-connection pipelining, graceful drain on shutdown.
//! - [`client`] — blocking client with connect/request timeouts; the
//!   `query --connect` / `stats --connect` / `shutdown --connect` CLI
//!   verbs are thin wrappers around it.
//! - [`http`] — hardened HTTP/1.1 scrape endpoint
//!   (`serve --metrics-listen`): `GET /metrics` serves the Prometheus
//!   exposition and `GET /healthz` a JSON health body, so stock
//!   scrapers and load balancers reach the observability plane without
//!   speaking the frame protocol.
//!
//! A networked query answers **bit-identically** to the in-process
//! engine across all serving modes (exhaustive, IVF-probed, DTW
//! re-ranked) — f64 values cross the wire as IEEE-754 bit patterns,
//! exactly like the index store.

// rustc-side twin of the xtask no-panic-in-serving rule: serving code
// must propagate errors. Test code (crate-wide `cfg(test)` under
// `cargo test`) is exempt on purpose.
#![cfg_attr(not(test), warn(clippy::unwrap_used, clippy::expect_used))]

pub mod client;
pub mod http;
pub mod protocol;
pub mod server;

pub use client::{
    connect_with_retry, is_timeout_error, jittered_backoff, Client, ClientConfig, NnReply,
    RetryConfig, TopKReply,
};
pub use http::{HttpConfig, HttpEndpoints, HttpServer};
pub use protocol::{NetRequest, NetResponse, WireClassStats, WireStageStats, WireStats};
pub use server::{NetServer, ServerConfig};
